//! Profiled layer-graph segmentation (ROADMAP item 2).
//!
//! Cuts a model's (topologically ordered) layer graph into at most
//! `max_segments` contiguous segments so the segments can run as a
//! pipeline across pool workers: a single hot stream of a deep model
//! then fills several workers instead of occupying one for its full
//! depth. Cut points are chosen from the per-layer [`CostTable`]
//! profile, the same approach as "Improving inference time in
//! multi-TPU systems with profiled model segmentation"
//! (arXiv:2503.01025).
//!
//! The objective is the pipeline's steady-state bottleneck plus what
//! the cuts themselves cost:
//!
//! ```text
//! minimize  max_s(segment_cost(s)) + Σ_cuts transfer_cost(cut)
//! ```
//!
//! where `segment_cost` is the sum of the member layers' best-case
//! (min-across-accelerators) modeled latency and `transfer_cost` is
//! the activation handoff at a cut boundary, priced like the DP
//! oracle's transfer score (write + read of the producer's output
//! activations at 70% of the slower side's DRAM bandwidth).
//!
//! The solver is exact: every achievable max-segment value is some
//! contiguous range sum, so it enumerates those candidates in
//! ascending order and, for each bound `M`, runs an `O(L·span·K)`
//! DP for the cheapest cut set whose segments all fit under `M`.
//! Candidates stop as soon as `M` alone exceeds the best objective
//! found (cut costs are non-negative), which keeps the scan near the
//! optimum in practice. This runs once per family at server start,
//! never on the request path.

use crate::accel::configs::MensaSystem;
use crate::model::ModelGraph;
use crate::scheduler::cache::CostTable;
use std::ops::Range;

/// A segmentation of a layer graph: `num_segments() + 1` boundary
/// indices plus the profiled compute cost of each segment. Segment
/// `s` covers layers `bounds[s] .. bounds[s + 1]`; the boundaries are
/// strictly increasing, starting at 0 and ending at the layer count,
/// so the segments partition the graph in topological order.
#[derive(Debug, Clone, PartialEq)]
pub struct SegmentPlan {
    bounds: Vec<usize>,
    costs: Vec<f64>,
    cut_cost: f64,
}

impl SegmentPlan {
    /// A single segment spanning all `layers` (the monolithic plan).
    pub fn monolithic(layers: usize, cost: f64) -> Self {
        Self { bounds: vec![0, layers], costs: vec![cost], cut_cost: 0.0 }
    }

    /// Number of segments.
    pub fn num_segments(&self) -> usize {
        self.costs.len()
    }

    /// Boundary indices (`num_segments() + 1` entries, first 0, last
    /// = layer count).
    pub fn bounds(&self) -> &[usize] {
        &self.bounds
    }

    /// The layer range of segment `s`.
    pub fn segment(&self, s: usize) -> Range<usize> {
        self.bounds[s]..self.bounds[s + 1]
    }

    /// Per-segment profiled compute cost.
    pub fn costs(&self) -> &[f64] {
        &self.costs
    }

    /// Total transfer cost across the chosen cut boundaries.
    pub fn cut_cost(&self) -> f64 {
        self.cut_cost
    }

    /// The solver objective this plan achieves: bottleneck segment
    /// cost plus total cut transfer cost.
    pub fn objective(&self) -> f64 {
        self.costs.iter().fold(0.0_f64, |a, &c| a.max(c)) + self.cut_cost
    }

    /// Each segment's share of the total compute cost (sums to 1).
    /// Used to scale a family's modeled device window down to one
    /// segment's slice of the pipeline.
    pub fn shares(&self) -> Vec<f64> {
        let total: f64 = self.costs.iter().sum();
        if total <= 0.0 {
            let even = 1.0 / self.costs.len().max(1) as f64;
            return vec![even; self.costs.len()];
        }
        self.costs.iter().map(|c| c / total).collect()
    }
}

/// Cut a linear layer profile into at most `max_segments` contiguous
/// segments minimizing `max(segment cost) + Σ cut costs`. `cut_costs`
/// holds the transfer cost of cutting after each non-final layer, so
/// `cut_costs.len() == layer_costs.len() - 1`.
///
/// Exact for the stated objective (see module docs for the candidate
/// enumeration + DP argument); ties resolve toward the smallest
/// feasible max-segment bound.
///
/// # Panics
/// Panics if `layer_costs` is empty, the lengths disagree, or
/// `max_segments` is 0.
pub fn cut(layer_costs: &[f64], cut_costs: &[f64], max_segments: usize) -> SegmentPlan {
    let l = layer_costs.len();
    assert!(l > 0, "cannot segment an empty layer profile");
    assert_eq!(cut_costs.len(), l - 1, "need one cut cost per interior boundary");
    assert!(max_segments > 0, "max_segments must be at least 1");
    let total: f64 = layer_costs.iter().sum();
    let k = max_segments.min(l);
    if k == 1 {
        return SegmentPlan::monolithic(l, total);
    }

    // Prefix sums: range_cost(i, j) = cost of layers i..j.
    let mut prefix = vec![0.0_f64; l + 1];
    for (i, &c) in layer_costs.iter().enumerate() {
        prefix[i + 1] = prefix[i] + c;
    }
    let range_cost = |i: usize, j: usize| prefix[j] - prefix[i];

    // Every achievable bottleneck is a contiguous range sum; the
    // widest single layer is a hard floor for feasibility.
    let floor = layer_costs.iter().fold(0.0_f64, |a, &c| a.max(c));
    let mut candidates: Vec<f64> = (0..l)
        .flat_map(|i| (i + 1..=l).map(move |j| range_cost(i, j)))
        .filter(|&m| m >= floor)
        .collect();
    candidates.sort_by(|a, b| a.total_cmp(b));
    candidates.dedup();

    let mut best: Option<SegmentPlan> = None;
    for &m in &candidates {
        if let Some(plan) = &best {
            if m >= plan.objective() {
                break; // cut costs are >= 0, so M alone already loses
            }
        }
        if let Some(plan) = cheapest_cuts_under(layer_costs, cut_costs, &prefix, k, m) {
            match &best {
                Some(b) if plan.objective() >= b.objective() => {}
                _ => best = Some(plan),
            }
        }
    }
    // The full range sum is always a candidate and always feasible
    // (one segment), so a plan exists.
    best.expect("at least the monolithic plan is feasible")
}

/// For a fixed bottleneck bound `m`: the min-total-cut-cost partition
/// into at most `k` segments each costing <= `m`, or `None` if no
/// such partition exists.
fn cheapest_cuts_under(
    layer_costs: &[f64],
    cut_costs: &[f64],
    prefix: &[f64],
    k: usize,
    m: f64,
) -> Option<SegmentPlan> {
    let l = layer_costs.len();
    const INF: f64 = f64::INFINITY;
    // dp[s][i]: min cut cost covering layers 0..i with exactly s
    // segments; parent[s][i] reconstructs the last boundary.
    let mut dp = vec![vec![INF; l + 1]; k + 1];
    let mut parent = vec![vec![usize::MAX; l + 1]; k + 1];
    dp[0][0] = 0.0;
    for s in 1..=k {
        for i in 1..=l {
            // Walk the last segment j..i backward until it outgrows m.
            let mut j = i;
            while j > 0 && prefix[i] - prefix[j - 1] <= m {
                j -= 1;
                let boundary = if j > 0 { cut_costs[j - 1] } else { 0.0 };
                let cand = dp[s - 1][j] + boundary;
                if cand < dp[s][i] {
                    dp[s][i] = cand;
                    parent[s][i] = j;
                }
            }
        }
    }
    let (segs, &cost) = dp
        .iter()
        .enumerate()
        .skip(1)
        .filter_map(|(s, row)| row[l].is_finite().then_some((s, &row[l])))
        .min_by(|a, b| a.1.total_cmp(b.1))?;

    let mut bounds = vec![l];
    let (mut s, mut i) = (segs, l);
    while i > 0 {
        let j = parent[s][i];
        bounds.push(j);
        s -= 1;
        i = j;
    }
    bounds.reverse();
    let costs =
        bounds.windows(2).map(|w| prefix[w[1]] - prefix[w[0]]).collect();
    Some(SegmentPlan { bounds, costs, cut_cost: cost })
}

/// Transfer seconds for handing `bytes` of activations across a cut:
/// one write plus one read at 70% of the bottleneck DRAM bandwidth —
/// the DP oracle's transfer-score idiom.
pub fn transfer_secs(bytes: u64, bw_gbps: f64) -> f64 {
    2.0 * bytes as f64 / (bw_gbps * 1e9 * 0.7)
}

/// Segment `model` for pipelined execution on `system`: per-layer
/// cost is the best case across the system's accelerators (each
/// segment independently lands on its argmin class downstream), and
/// each interior boundary is priced at the producer layer's output
/// activation transfer over the system's slowest DRAM interface.
pub fn plan_for_model(
    system: &MensaSystem,
    model: &ModelGraph,
    table: &CostTable,
    max_segments: usize,
) -> SegmentPlan {
    assert_eq!(table.num_layers(), model.len(), "cost table must match the model");
    assert!(!system.is_empty(), "cannot plan against an empty system");
    let accels = table.num_accels();
    let layer_costs: Vec<f64> = (0..model.len())
        .map(|i| (0..accels).map(|a| table.cost(i, a).latency_s).fold(f64::INFINITY, f64::min))
        .collect();
    let min_bw = system.accels.iter().map(|a| a.dram_bw_gbps).fold(f64::INFINITY, f64::min);
    let cut_costs: Vec<f64> = model.layers()[..model.len() - 1]
        .iter()
        .map(|layer| transfer_secs(layer.output_act_bytes(), min_bw))
        .collect();
    cut(&layer_costs, &cut_costs, max_segments)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::configs::mensa_g;
    use crate::model::zoo;
    use crate::util::rng::Rng;

    /// Brute force over every cut subset (<= 8 layers): the reference
    /// optimum for the composite objective.
    fn brute_force(layer_costs: &[f64], cut_costs: &[f64], max_segments: usize) -> f64 {
        let l = layer_costs.len();
        assert!(l <= 8, "brute force is exponential in layer count");
        let mut best = f64::INFINITY;
        for mask in 0u32..(1 << (l - 1)) {
            if (mask.count_ones() as usize) + 1 > max_segments {
                continue;
            }
            let mut max_seg = 0.0_f64;
            let mut seg = 0.0;
            let mut cuts = 0.0;
            for (i, &c) in layer_costs.iter().enumerate() {
                seg += c;
                if i + 1 < l && mask & (1 << i) != 0 {
                    max_seg = max_seg.max(seg);
                    seg = 0.0;
                    cuts += cut_costs[i];
                }
            }
            best = best.min(max_seg.max(seg) + cuts);
        }
        best
    }

    fn assert_partitions(plan: &SegmentPlan, layers: usize, max_segments: usize) {
        let b = plan.bounds();
        assert_eq!(b.first(), Some(&0));
        assert_eq!(b.last(), Some(&layers));
        assert!(b.windows(2).all(|w| w[0] < w[1]), "bounds must strictly increase: {b:?}");
        assert_eq!(b.len(), plan.num_segments() + 1);
        assert!(plan.num_segments() <= max_segments);
        let shares = plan.shares();
        assert!((shares.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    fn random_profile(rng: &mut Rng, layers: usize) -> (Vec<f64>, Vec<f64>) {
        let costs: Vec<f64> = (0..layers).map(|_| rng.log_uniform(1e-6, 1e-3)).collect();
        // Cut costs span "free" to "comparable to a layer", so some
        // draws make cutting genuinely unattractive.
        let cuts: Vec<f64> = (0..layers - 1).map(|_| rng.log_uniform(1e-8, 1e-4)).collect();
        (costs, cuts)
    }

    #[test]
    fn single_segment_when_capped_at_one() {
        let plan = cut(&[1.0, 2.0, 3.0], &[0.1, 0.1], 1);
        assert_eq!(plan.bounds(), &[0, 3]);
        assert_eq!(plan.costs(), &[6.0]);
        assert_eq!(plan.cut_cost(), 0.0);
    }

    #[test]
    fn even_split_when_cuts_are_free() {
        let plan = cut(&[1.0; 4], &[0.0; 3], 2);
        assert_eq!(plan.bounds(), &[0, 2, 4]);
        assert_eq!(plan.costs(), &[2.0, 2.0]);
        assert!((plan.objective() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn expensive_boundary_is_avoided() {
        // Cutting at the balanced midpoint costs 10; the off-center
        // boundary is free and still beats not cutting at all.
        let plan = cut(&[1.0, 1.0, 1.0, 1.0], &[0.0, 10.0, 0.0], 2);
        assert_ne!(plan.bounds(), &[0, 2, 4], "must dodge the expensive cut");
        assert!((plan.objective() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn prohibitive_cut_costs_keep_the_model_whole() {
        let plan = cut(&[1.0, 1.0, 1.0, 1.0], &[100.0; 3], 4);
        assert_eq!(plan.num_segments(), 1, "cuts cost more than they save");
        assert_eq!(plan.bounds(), &[0, 4]);
    }

    #[test]
    fn matches_brute_force_on_small_random_graphs() {
        let mut rng = Rng::new(0x5e91);
        for trial in 0..200 {
            let layers = rng.range_usize(1, 8);
            let (costs, cuts) = random_profile(&mut rng, layers);
            let k = rng.range_usize(1, 4);
            let plan = cut(&costs, &cuts, k);
            assert_partitions(&plan, layers, k);
            let reference = brute_force(&costs, &cuts, k);
            assert!(
                (plan.objective() - reference).abs() <= 1e-9 * reference.max(1.0),
                "trial {trial}: solver {} vs brute force {reference} \
                 (layers {layers}, k {k})",
                plan.objective(),
            );
        }
    }

    #[test]
    fn segments_partition_and_costs_are_range_sums() {
        let mut rng = Rng::new(0xcafe);
        for _ in 0..100 {
            let layers = rng.range_usize(1, 40);
            let (costs, cuts) = random_profile(&mut rng, layers);
            let k = rng.range_usize(1, 6);
            let plan = cut(&costs, &cuts, k);
            assert_partitions(&plan, layers, k);
            for (s, seg_cost) in plan.costs().iter().enumerate() {
                let expect: f64 = costs[plan.segment(s)].iter().sum();
                assert!((seg_cost - expect).abs() <= 1e-9 * expect.max(1.0));
            }
            let expect_cuts: f64 =
                plan.bounds()[1..plan.bounds().len() - 1].iter().map(|&b| cuts[b - 1]).sum();
            assert!((plan.cut_cost() - expect_cuts).abs() <= 1e-9 * expect_cuts.max(1.0));
        }
    }

    #[test]
    fn widening_the_budget_never_hurts() {
        let mut rng = Rng::new(0xbeef);
        for _ in 0..50 {
            let layers = rng.range_usize(2, 24);
            let (costs, cuts) = random_profile(&mut rng, layers);
            let mut prev = f64::INFINITY;
            for k in 1..=6 {
                let obj = cut(&costs, &cuts, k).objective();
                assert!(obj <= prev + 1e-12, "k={k} worsened {prev} -> {obj}");
                prev = obj;
            }
        }
    }

    #[test]
    fn plan_for_model_segments_a_zoo_model() {
        let system = mensa_g();
        let model = zoo::lstm(2);
        let table = CostTable::build(&system, &model);
        let plan = plan_for_model(&system, &model, &table, 4);
        assert_partitions(&plan, model.len(), 4);
        assert!(plan.num_segments() >= 2, "a deep LSTM should split: {:?}", plan.bounds());
        // Splitting must beat the monolithic bottleneck.
        let total: f64 = plan.costs().iter().sum();
        assert!(plan.objective() < total);
    }
}
