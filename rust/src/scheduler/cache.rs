//! Schedule/cost caching for the serving hot path (§Perf).
//!
//! Two layers of reuse keep the request path off the scheduler's and
//! simulator's cold paths:
//!
//! * [`CostTable`] — the per-`(layer, accelerator)` dataflow costs for
//!   one `(model, system)` pair, computed **once** and shared by
//!   Phase I, Phase II, the DP [`oracle`](super::oracle), and the
//!   simulator. Before this table existed, `schedule` + `run` each
//!   re-invoked `cfg.dataflow.cost(..)` for the same layers.
//! * [`ScheduleCache`] — a `RwLock`-guarded memo of
//!   `(system, model) → (Mapping, RunReport)`. The coordinator's
//!   `family_sim_costs()` and any per-request re-simulation hit this
//!   instead of re-running the two-phase scheduler and the simulator
//!   from scratch; a hit is a read-lock plus an `Arc` clone.
//!
//! # Invalidation rules
//!
//! Entries are keyed by `(system.name, model.name)` **plus a
//! structural hash** of both: every accelerator's geometry/dataflow
//! fields and every layer's structural parameters feed an FNV
//! digest, so a config sweep that reuses a name with different
//! hardware (or a rebuilt model under an old name) misses the cache
//! instead of serving a stale schedule. Remaining caveats:
//!
//! * mutating an accelerator or model **in place** after it was cached
//!   still leaves a stale entry reachable through the *old* structure
//!   — call [`ScheduleCache::invalidate`] with the system name (or
//!   [`ScheduleCache::clear`]) first; the structural hash protects
//!   name *reuse*, not aliased mutation;
//! * the hash covers accelerator fields and per-layer structure (name,
//!   kind parameters, group); exotic sweeps that vary only the graph
//!   edge list between identically-named, identically-parameterized
//!   layers still need distinct model names;
//! * the process-wide [`ScheduleCache::global`] instance is shared by
//!   every server in the process, which is exactly what makes a second
//!   `Server::start` cheap.

use crate::accel::configs::MensaSystem;
use crate::accel::dataflow::LayerCost;
use crate::model::{LayerId, ModelGraph};
use crate::scheduler::{Mapping, MensaScheduler};
use crate::sim::{RunReport, Simulator};
use std::collections::HashMap;
use std::sync::{Arc, OnceLock, RwLock};

/// Cache key: display names (for [`ScheduleCache::invalidate`]) plus
/// the structural digest that catches name reuse across sweeps.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct CacheKey {
    system: String,
    model: String,
    structure: u64,
}

/// Incremental FNV-1a digest over heterogeneous fields (one wrapper
/// around the project's single FNV loop in `util`).
struct Digest(u64);

impl Digest {
    fn new() -> Self {
        Digest(crate::util::FNV1A_64_OFFSET)
    }

    fn bytes(&mut self, bytes: &[u8]) {
        self.0 = crate::util::fnv1a_64_extend(self.0, bytes);
    }

    fn str(&mut self, s: &str) {
        self.bytes(s.as_bytes());
        self.bytes(&[0xFF]); // field separator
    }

    fn u64v(&mut self, v: u64) {
        self.bytes(&v.to_le_bytes());
    }

    fn f64v(&mut self, v: f64) {
        self.u64v(v.to_bits());
    }
}

/// Structural digest of a (system, model) pair: accelerator geometry,
/// dataflow/memory kinds, and each layer's structural parameters.
/// Deliberately excludes `AccelConfig::buf_energy_cache` (a lazily
/// initialized memo whose state must not affect identity).
fn structural_hash(system: &MensaSystem, model: &ModelGraph) -> u64 {
    use std::fmt::Write as _;
    let mut d = Digest::new();
    let mut buf = String::with_capacity(128);
    d.str(&system.name);
    d.u64v(system.accels.len() as u64);
    for a in &system.accels {
        d.str(&a.name);
        d.u64v(a.pe_rows as u64);
        d.u64v(a.pe_cols as u64);
        d.f64v(a.clock_ghz);
        d.u64v(a.param_buf_bytes);
        d.u64v(a.act_buf_bytes);
        d.u64v(a.pe_reg_bytes);
        d.f64v(a.dram_bw_gbps);
        buf.clear();
        let _ = write!(buf, "{:?}/{:?}", a.memory, a.dataflow);
        d.str(&buf);
    }
    d.str(&model.name);
    d.str(model.kind.name());
    d.u64v(model.len() as u64);
    for layer in model.layers() {
        // Layer's Debug form spells out name, kind parameters, and
        // group — exactly the structural surface the cost model reads.
        // One reused buffer keeps the per-lookup cost to formatting,
        // far below the ≥10x hit-vs-cold bar.
        buf.clear();
        let _ = write!(buf, "{layer:?}");
        d.str(&buf);
    }
    d.0
}

/// Per-layer × per-accelerator dataflow costs for one (model, system)
/// pair, computed once up front.
#[derive(Debug, Clone)]
pub struct CostTable {
    per_layer: Vec<Vec<LayerCost>>,
}

impl CostTable {
    /// Cost every layer of `model` on every accelerator of `system`.
    pub fn build(system: &MensaSystem, model: &ModelGraph) -> Self {
        let per_layer = model
            .layers()
            .iter()
            .map(|layer| {
                system.accels.iter().map(|cfg| cfg.dataflow.cost(cfg, layer)).collect()
            })
            .collect();
        Self { per_layer }
    }

    /// The cost of `layer` on accelerator `accel`.
    ///
    /// # Panics
    /// Panics if either index is out of range for the table.
    pub fn cost(&self, layer: LayerId, accel: usize) -> &LayerCost {
        &self.per_layer[layer][accel]
    }

    /// Number of layers covered.
    pub fn num_layers(&self) -> usize {
        self.per_layer.len()
    }

    /// `true` if the table covers no layers.
    pub fn is_empty(&self) -> bool {
        self.per_layer.is_empty()
    }

    /// Number of accelerators covered.
    pub fn num_accels(&self) -> usize {
        self.per_layer.first().map_or(0, Vec::len)
    }
}

/// A cached scheduling outcome: the Mensa mapping plus the simulated
/// run report for one (system, model) pair.
#[derive(Debug)]
pub struct ScheduledCost {
    /// The two-phase Mensa schedule.
    pub mapping: Mapping,
    /// The simulator's report for that schedule.
    pub report: RunReport,
}

/// Memoizes `(system, model) → Arc<ScheduledCost>` behind a `RwLock`.
///
/// Concurrent readers (the executor-pool workers) share hits without
/// contention; a miss computes outside the lock and the first writer
/// wins (losers adopt the existing entry), so results are stable even
/// under racing cold lookups.
#[derive(Debug, Default)]
pub struct ScheduleCache {
    entries: RwLock<HashMap<CacheKey, Arc<ScheduledCost>>>,
}

impl ScheduleCache {
    /// An empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// The process-wide shared cache.
    pub fn global() -> &'static ScheduleCache {
        static GLOBAL: OnceLock<ScheduleCache> = OnceLock::new();
        GLOBAL.get_or_init(ScheduleCache::new)
    }

    /// Schedule + simulate `model` on `system`, memoized. A hit is a
    /// structural-hash computation, a read-lock, and an `Arc` clone; a
    /// miss builds one [`CostTable`] and shares it between the
    /// scheduler and the simulator.
    pub fn get_or_compute(&self, system: &MensaSystem, model: &ModelGraph) -> Arc<ScheduledCost> {
        let key = CacheKey {
            system: system.name.clone(),
            model: model.name.clone(),
            structure: structural_hash(system, model),
        };
        if let Some(hit) = self.entries.read().expect("schedule cache lock").get(&key) {
            return Arc::clone(hit);
        }
        // Miss: compute outside the lock (this is the slow path).
        let table = CostTable::build(system, model);
        let mapping = MensaScheduler::new(system).schedule_with_table(model, &table);
        let report = Simulator::new(system).run_with_costs(model, &mapping, &table);
        let fresh = Arc::new(ScheduledCost { mapping, report });
        let mut entries = self.entries.write().expect("schedule cache lock");
        Arc::clone(entries.entry(key).or_insert(fresh))
    }

    /// Drop every entry for a system (call after mutating it in place).
    pub fn invalidate(&self, system_name: &str) {
        self.entries
            .write()
            .expect("schedule cache lock")
            .retain(|key, _| key.system != system_name);
    }

    /// Drop all entries.
    pub fn clear(&self) {
        self.entries.write().expect("schedule cache lock").clear();
    }

    /// Number of cached entries.
    pub fn len(&self) -> usize {
        self.entries.read().expect("schedule cache lock").len()
    }

    /// `true` if nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::configs;
    use crate::model::zoo;
    use std::time::Instant;

    #[test]
    fn cost_table_matches_direct_dataflow_costs() {
        let system = configs::mensa_g();
        let model = zoo::cnn(0);
        let table = CostTable::build(&system, &model);
        assert_eq!(table.num_layers(), model.len());
        assert_eq!(table.num_accels(), system.len());
        assert!(!table.is_empty());
        for (id, layer) in model.iter() {
            for (a, cfg) in system.accels.iter().enumerate() {
                let direct = cfg.dataflow.cost(cfg, layer);
                let cached = table.cost(id, a);
                assert_eq!(cached.latency_s, direct.latency_s, "layer {id} accel {a}");
                assert_eq!(cached.macs, direct.macs);
                assert_eq!(cached.energy.total_j(), direct.energy.total_j());
            }
        }
    }

    #[test]
    fn cached_result_matches_uncached_pipeline() {
        let system = configs::mensa_g();
        let model = zoo::lstm(2);
        let cache = ScheduleCache::new();
        let cached = cache.get_or_compute(&system, &model);
        let mapping = MensaScheduler::new(&system).schedule(&model);
        let report = Simulator::new(&system).run(&model, &mapping);
        assert_eq!(cached.mapping.as_slice(), mapping.as_slice());
        assert_eq!(cached.report.total_latency_s, report.total_latency_s);
        assert_eq!(cached.report.total_energy_j(), report.total_energy_j());
    }

    #[test]
    fn second_lookup_shares_the_same_entry() {
        let system = configs::mensa_g();
        let model = zoo::cnn(1);
        let cache = ScheduleCache::new();
        let a = cache.get_or_compute(&system, &model);
        let b = cache.get_or_compute(&system, &model);
        assert!(Arc::ptr_eq(&a, &b), "hit must reuse the cached Arc");
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn distinct_models_and_systems_get_distinct_entries() {
        let mensa = configs::mensa_g();
        let base = configs::baseline_system();
        let cache = ScheduleCache::new();
        cache.get_or_compute(&mensa, &zoo::cnn(0));
        cache.get_or_compute(&mensa, &zoo::cnn(1));
        cache.get_or_compute(&base, &zoo::cnn(0));
        assert_eq!(cache.len(), 3);
        cache.invalidate(&mensa.name);
        assert_eq!(cache.len(), 1, "only the baseline entry survives");
        cache.clear();
        assert!(cache.is_empty());
    }

    #[test]
    fn cache_hit_is_at_least_10x_faster_than_cold_path() {
        // The acceptance bar for the serving hot path: a warm
        // `family_sim_costs()`-equivalent lookup must beat re-running
        // the scheduler + simulator by ≥ 10x. The real ratio is orders
        // of magnitude; 10x leaves headroom for noisy CI machines.
        let system = configs::mensa_g();
        let model = zoo::cnn(0);
        let mut cold_ns = f64::INFINITY;
        for _ in 0..3 {
            let cache = ScheduleCache::new();
            let t = Instant::now();
            std::hint::black_box(cache.get_or_compute(&system, &model));
            cold_ns = cold_ns.min(t.elapsed().as_nanos() as f64);
        }
        let cache = ScheduleCache::new();
        cache.get_or_compute(&system, &model);
        let iters = 200u32;
        let t = Instant::now();
        for _ in 0..iters {
            std::hint::black_box(cache.get_or_compute(&system, &model));
        }
        let warm_ns = t.elapsed().as_nanos() as f64 / f64::from(iters);
        assert!(
            warm_ns * 10.0 < cold_ns,
            "warm hit {warm_ns:.0} ns/lookup vs cold {cold_ns:.0} ns — cache not ≥ 10x faster"
        );
    }

    #[test]
    fn reused_names_with_different_structure_do_not_collide() {
        // The ROADMAP invalidation hazard: a config sweep constructs a
        // *different* system under the same name. The structural hash
        // must keep the entries apart instead of serving the first
        // system's schedule for the second.
        let cache = ScheduleCache::new();
        let model = zoo::cnn(0);
        let base = configs::mensa_g();
        let mut tweaked = configs::mensa_g(); // same name...
        tweaked.accels[0].pe_rows *= 2; // ...different hardware
        let a = cache.get_or_compute(&base, &model);
        let b = cache.get_or_compute(&tweaked, &model);
        assert_eq!(base.name, tweaked.name, "the hazard under test");
        assert!(!Arc::ptr_eq(&a, &b), "structural change must miss the cache");
        assert_eq!(cache.len(), 2);
        // And the same structure still hits.
        let c = cache.get_or_compute(&configs::mensa_g(), &model);
        assert!(Arc::ptr_eq(&a, &c), "identical structure must hit");
        // Models reusing a name with different layers split too.
        let mut renamed = zoo::cnn(1);
        renamed.name = model.name.clone();
        let d = cache.get_or_compute(&base, &renamed);
        assert!(!Arc::ptr_eq(&a, &d));
        // invalidate() still keys on the system display name.
        cache.invalidate(&base.name);
        assert!(cache.is_empty(), "all Mensa-G entries dropped by name");
    }

    #[test]
    fn global_cache_is_shared() {
        let system = configs::mensa_g();
        let model = zoo::transducer(0);
        let a = ScheduleCache::global().get_or_compute(&system, &model);
        let b = ScheduleCache::global().get_or_compute(&system, &model);
        assert!(Arc::ptr_eq(&a, &b));
    }
}
