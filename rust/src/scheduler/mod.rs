//! The Mensa runtime scheduler (§4.2).
//!
//! Phase I assigns every layer its *ideal* accelerator in isolation:
//! the layer's family determines affinity (F1/F2 → Pascal, F3 → Pavlov,
//! F4/F5 → Jacquard, §5.2.1), with outliers resolved by
//! minimum energy-delay product over the system's cost models.
//!
//! Phase II walks the layers sequentially and decides, for each layer,
//! whether to run it on its ideal accelerator or on the previous
//! layer's destination, trading communication against execution
//! optimality with the paper's two empirical rules:
//!
//! 1. if running on the previous destination would take **more than 2x**
//!    the ideal accelerator's compute time (the "MAC operations ... 2x
//!    higher than the compute resources available" rule), move to the
//!    ideal accelerator;
//! 2. if the parameter data the previous destination would need to
//!    fetch exceeds the activation data that must be shipped to the
//!    ideal accelerator, **and** parameter reuse is low (FLOP/B < 64),
//!    move to the ideal accelerator;
//! 3. otherwise stay on the previous destination.
//!
//! This module also provides an exhaustive DP [`oracle`] (the
//! hypothetical scheduler §4.2 mentions Mensa's heuristic may fall
//! short of) and the Phase-I-only ablation, both exercised by
//! `benches/ablate_scheduler.rs`.
//!
//! # Cost reuse (§Perf)
//!
//! Every per-layer dataflow evaluation a schedule needs is hoisted into
//! a [`CostTable`] built once per (model, system): Phase I's EDP
//! fallback, Phase II's 2x rule, the DP [`oracle`], and the simulator
//! (via [`Simulator::run_with_costs`](crate::sim::Simulator::run_with_costs))
//! all read the same table instead of re-invoking
//! `cfg.dataflow.cost(..)`. Whole (mapping, report) outcomes are
//! additionally memoized by [`ScheduleCache`] — see [`cache`] for the
//! invalidation rules.

pub mod cache;
pub mod segment;

pub use cache::{CostTable, ScheduleCache, ScheduledCost};
pub use segment::SegmentPlan;

use crate::accel::configs::MensaSystem;
use crate::characterize::{classify, Family, LayerMetrics};
use crate::model::{LayerId, ModelGraph};

/// A layer → accelerator assignment for one model.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Mapping {
    assignment: Vec<usize>,
}

impl Mapping {
    /// Wrap an explicit assignment vector.
    pub fn new(assignment: Vec<usize>) -> Self {
        Self { assignment }
    }

    /// Every layer on the same accelerator.
    pub fn uniform(len: usize, accel: usize) -> Self {
        Self { assignment: vec![accel; len] }
    }

    /// Number of layers covered.
    pub fn len(&self) -> usize {
        self.assignment.len()
    }

    /// `true` if the mapping covers no layers.
    pub fn is_empty(&self) -> bool {
        self.assignment.is_empty()
    }

    /// Accelerator id of a layer.
    pub fn accel_of(&self, layer: LayerId) -> usize {
        self.assignment[layer]
    }

    /// The raw assignment slice.
    pub fn as_slice(&self) -> &[usize] {
        &self.assignment
    }

    /// Count of layers per accelerator id.
    pub fn histogram(&self, num_accels: usize) -> Vec<usize> {
        let mut h = vec![0usize; num_accels];
        for &a in &self.assignment {
            h[a] += 1;
        }
        h
    }

    /// Number of accelerator switches along the topological order — a
    /// proxy for §5.6's "models typically communicate between
    /// accelerators only 4–5 times".
    pub fn switch_count(&self) -> usize {
        self.assignment.windows(2).filter(|w| w[0] != w[1]).count()
    }
}

/// Family → preferred dataflow, per §5.2.1's accelerator assignment.
fn preferred_dataflow(family: Family) -> Option<crate::accel::DataflowKind> {
    use crate::accel::DataflowKind as D;
    match family {
        Family::F1 | Family::F2 => Some(D::PascalOs),
        Family::F3 => Some(D::PavlovWs),
        Family::F4 | Family::F5 => Some(D::JacquardWs),
        Family::Outlier => None,
    }
}

/// The Mensa scheduler.
#[derive(Debug, Clone)]
pub struct MensaScheduler<'a> {
    system: &'a MensaSystem,
    /// Run Phase II (communication-aware reassignment). Disable for the
    /// Phase-I-only ablation.
    pub phase2: bool,
}

impl<'a> MensaScheduler<'a> {
    /// Create a scheduler for a system.
    pub fn new(system: &'a MensaSystem) -> Self {
        Self { system, phase2: true }
    }

    /// Phase-I-only variant (ablation).
    pub fn phase1_only(system: &'a MensaSystem) -> Self {
        Self { system, phase2: false }
    }

    /// Min energy-delay-product accelerator for a layer (used for
    /// outliers and when the preferred dataflow is absent).
    fn best_by_edp(&self, table: &CostTable, id: LayerId) -> usize {
        let mut best = 0usize;
        let mut best_edp = f64::INFINITY;
        for a in 0..self.system.len() {
            let c = table.cost(id, a);
            let edp = c.latency_s * c.energy.total_j().max(1e-18);
            if edp < best_edp {
                best_edp = edp;
                best = a;
            }
        }
        best
    }

    /// Phase I assignment plus the per-layer metrics it computed
    /// (Phase II reuses them instead of re-deriving — §Perf).
    fn phase1_with_metrics(
        &self,
        model: &ModelGraph,
        table: &CostTable,
    ) -> (Vec<usize>, Vec<LayerMetrics>) {
        let metrics: Vec<LayerMetrics> =
            model.layers().iter().map(LayerMetrics::of).collect();
        let assignment = metrics
            .iter()
            .enumerate()
            .map(|(id, m)| {
                let family = classify(m);
                match preferred_dataflow(family)
                    .and_then(|d| self.system.accels.iter().position(|a| a.dataflow == d))
                {
                    Some(accel) => accel,
                    None => self.best_by_edp(table, id),
                }
            })
            .collect();
        (assignment, metrics)
    }

    /// Phase I: ideal accelerator per layer in isolation.
    pub fn phase1(&self, model: &ModelGraph) -> Mapping {
        if self.system.len() == 1 {
            return Mapping::uniform(model.len(), 0);
        }
        let table = CostTable::build(self.system, model);
        Mapping::new(self.phase1_with_metrics(model, &table).0)
    }

    /// Full schedule: Phase I + (optionally) Phase II. Builds a fresh
    /// [`CostTable`]; callers that already have one (or also want to
    /// simulate) should use [`schedule_with_table`](Self::schedule_with_table)
    /// to share it.
    pub fn schedule(&self, model: &ModelGraph) -> Mapping {
        if self.system.len() == 1 {
            return Mapping::uniform(model.len(), 0);
        }
        let table = CostTable::build(self.system, model);
        self.schedule_with_table(model, &table)
    }

    /// Full schedule reusing a prebuilt per-layer cost table (the
    /// serving path builds one table per (model, system) and shares it
    /// with the simulator — see [`cache::ScheduleCache`]).
    ///
    /// # Panics
    /// Panics if `table` does not cover `model`'s layers and this
    /// system's accelerators.
    pub fn schedule_with_table(&self, model: &ModelGraph, table: &CostTable) -> Mapping {
        if self.system.len() == 1 || model.is_empty() {
            return Mapping::uniform(model.len(), 0);
        }
        assert_eq!(table.num_layers(), model.len(), "cost table/model length mismatch");
        assert_eq!(table.num_accels(), self.system.len(), "cost table/system width mismatch");
        let (ideal, metrics) = self.phase1_with_metrics(model, table);
        if !self.phase2 || model.is_empty() {
            return Mapping::new(ideal);
        }

        let mut assignment = Vec::with_capacity(model.len());
        // The first layer runs on its ideal accelerator.
        assignment.push(ideal[0]);
        for id in 1..model.len() {
            let ideal_id = ideal[id];
            // destination_{i-1}: where the sequential predecessor ended
            // up (the paper's sequential walk).
            let prev_dest = assignment[id - 1];
            if prev_dest == ideal_id {
                // Footnote 5: analysis skipped.
                assignment.push(ideal_id);
                continue;
            }
            let m = &metrics[id];

            // Rule 2 first — it needs no dataflow costing: parameter
            // fetch on the suboptimal accelerator outweighs shipping
            // the activations, with low reuse. Parameter traffic on a
            // non-ideal accelerator is at least the footprint (times
            // the per-step streaming for recurrent layers).
            let act_to_move: u64 =
                model.preds(id).iter().map(|&p| model.layer(p).output_act_bytes()).sum();
            let param_fetch = m.param_bytes as f64
                * if m.recurrent { m.invocations as f64 } else { 1.0 };
            let rule2 =
                param_fetch > act_to_move as f64 && m.param_flop_per_byte < 64.0;
            if rule2 {
                assignment.push(ideal_id);
                continue;
            }

            // Rule 1: 2x compute-resources rule — staying would more
            // than double execution time vs the ideal accelerator.
            let cost_prev = table.cost(id, prev_dest);
            let cost_ideal = table.cost(id, ideal_id);
            let rule1 = cost_prev.latency_s > 2.0 * cost_ideal.latency_s;

            assignment.push(if rule1 { ideal_id } else { prev_dest });
        }
        Mapping::new(assignment)
    }
}

/// Exhaustive DP scheduler: minimizes `latency + lambda * energy` over
/// all per-layer assignments, with DRAM transfer costs charged on
/// edges. The DP state is the assignment of the sequential predecessor;
/// transfer costs on skip edges are approximated against the
/// predecessor's DP choice (exact for chain models; see DESIGN.md).
pub fn oracle(system: &MensaSystem, model: &ModelGraph, lambda: f64) -> Mapping {
    let n_acc = system.len();
    if n_acc == 1 || model.is_empty() {
        return Mapping::uniform(model.len(), 0);
    }
    let n = model.len();
    // Static power runs for the whole inference regardless of where a
    // layer executes, so each second of latency costs both time and
    // `static_w` joules — fold it in so the DP optimizes the same
    // objective the simulator reports.
    let static_w = system.total_leakage_w() + crate::energy::DRAM_STATIC_W;
    let sec_weight = 1.0 + lambda * static_w;
    // Per-(layer, accel) execution scores read from one shared table
    // instead of re-running the dataflow models inside the DP.
    let table = CostTable::build(system, model);
    let score = |i: usize, a: usize| -> f64 {
        let c = table.cost(i, a);
        c.latency_s * sec_weight + lambda * c.energy.total_j()
    };
    // Transfer score between accelerators for `bytes`.
    let tscore = |src: usize, dst: usize, bytes: f64| -> f64 {
        if src == dst || bytes == 0.0 {
            return 0.0;
        }
        let a = &system.accels[src];
        let b = &system.accels[dst];
        let bw = a.dram_bw_gbps.min(b.dram_bw_gbps) * 1e9 * 0.7;
        let secs = 2.0 * bytes / bw;
        let energy = bytes * (a.memory.energy_per_byte() + b.memory.energy_per_byte());
        secs * sec_weight + lambda * energy
    };

    // dp[a] = best cumulative score with layer i on accelerator a.
    let mut dp = vec![0.0f64; n_acc];
    let mut back: Vec<Vec<usize>> = Vec::with_capacity(n);
    for a in 0..n_acc {
        dp[a] = score(0, a);
    }
    back.push(vec![0; n_acc]);
    for i in 1..n {
        let in_bytes: f64 = model
            .preds(i)
            .iter()
            .map(|&p| model.layer(p).output_act_bytes() as f64)
            .sum();
        let mut next = vec![f64::INFINITY; n_acc];
        let mut choice = vec![0usize; n_acc];
        for a in 0..n_acc {
            let exec = score(i, a);
            for prev in 0..n_acc {
                let total = dp[prev] + exec + tscore(prev, a, in_bytes);
                if total < next[a] {
                    next[a] = total;
                    choice[a] = prev;
                }
            }
        }
        dp = next;
        back.push(choice);
    }
    // Reconstruct.
    let mut best_last = 0usize;
    for a in 1..n_acc {
        if dp[a] < dp[best_last] {
            best_last = a;
        }
    }
    let mut assignment = vec![0usize; n];
    assignment[n - 1] = best_last;
    for i in (1..n).rev() {
        assignment[i - 1] = back[i][assignment[i]];
    }
    Mapping::new(assignment)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::configs;
    use crate::model::zoo;
    use crate::model::LayerKind;
    use crate::sim::Simulator;

    #[test]
    fn mapping_helpers() {
        let m = Mapping::new(vec![0, 0, 1, 2, 2, 0]);
        assert_eq!(m.len(), 6);
        assert_eq!(m.accel_of(3), 2);
        assert_eq!(m.histogram(3), vec![3, 1, 2]);
        assert_eq!(m.switch_count(), 3);
        assert_eq!(Mapping::uniform(4, 1).switch_count(), 0);
    }

    #[test]
    fn schedule_with_table_matches_schedule() {
        // The table-sharing fast path must be behavior-preserving.
        let sys = configs::mensa_g();
        for model in [zoo::cnn(2), zoo::lstm(1), zoo::transducer(0)] {
            let table = CostTable::build(&sys, &model);
            let fresh = MensaScheduler::new(&sys).schedule(&model);
            let shared = MensaScheduler::new(&sys).schedule_with_table(&model, &table);
            assert_eq!(fresh.as_slice(), shared.as_slice(), "{}", model.name);
        }
    }

    #[test]
    fn single_accel_system_trivial_schedule() {
        let sys = configs::baseline_system();
        let model = zoo::cnn(0);
        let m = MensaScheduler::new(&sys).schedule(&model);
        assert!(m.as_slice().iter().all(|&a| a == 0));
    }

    #[test]
    fn phase1_routes_families_to_their_accelerators() {
        // §5.2.1: F1/F2 -> Pascal, F3 -> Pavlov, F4/F5 -> Jacquard.
        let sys = configs::mensa_g();
        let model = zoo::lstm(0);
        let m = MensaScheduler::new(&sys).phase1(&model);
        let pavlov = sys.find("Pavlov").unwrap();
        for (id, layer) in model.iter() {
            if matches!(layer.kind, LayerKind::LstmGate { .. }) {
                assert_eq!(m.accel_of(id), pavlov, "gate {} not on Pavlov", layer.name);
            }
        }
    }

    #[test]
    fn phase1_routes_compute_layers_to_pascal() {
        let sys = configs::mensa_g();
        let model = zoo::cnn(0);
        let m = MensaScheduler::new(&sys).phase1(&model);
        let pascal = sys.find("Pascal").unwrap();
        // The early high-reuse convs belong on Pascal.
        let early: Vec<usize> = model
            .iter()
            .filter(|(_, l)| l.name.starts_with("s56/conv"))
            .map(|(id, _)| id)
            .collect();
        assert!(!early.is_empty());
        for id in early {
            assert_eq!(m.accel_of(id), pascal);
        }
    }

    #[test]
    fn phase2_reduces_communication() {
        // Phase II exists to avoid chatty schedules: it must never
        // switch more often than Phase I alone on a CNN.
        let sys = configs::mensa_g();
        for i in [0usize, 4, 9] {
            let model = zoo::cnn(i);
            let p1 = MensaScheduler::phase1_only(&sys).schedule(&model);
            let p2 = MensaScheduler::new(&sys).schedule(&model);
            assert!(
                p2.switch_count() <= p1.switch_count(),
                "{}: phase2 {} vs phase1 {}",
                model.name,
                p2.switch_count(),
                p1.switch_count()
            );
        }
    }

    #[test]
    fn phase2_keeps_lstm_gates_on_pavlov() {
        // Gates have huge parameter fetches and low FLOP/B: rule 2 must
        // pull them to Pavlov even when the previous layer ran elsewhere.
        let sys = configs::mensa_g();
        let model = zoo::rcnn(0); // CNN front-end then LSTM layers
        let m = MensaScheduler::new(&sys).schedule(&model);
        let pavlov = sys.find("Pavlov").unwrap();
        let mut gates = 0;
        let mut on_pavlov = 0;
        for (id, layer) in model.iter() {
            if matches!(layer.kind, LayerKind::LstmGate { .. }) {
                gates += 1;
                if m.accel_of(id) == pavlov {
                    on_pavlov += 1;
                }
            }
        }
        assert!(gates > 0);
        assert!(
            on_pavlov * 10 >= gates * 9,
            "only {on_pavlov}/{gates} gates on Pavlov"
        );
    }

    #[test]
    fn mensa_schedule_beats_all_on_one_for_sequence_models() {
        let sys = configs::mensa_g();
        let sim = Simulator::new(&sys);
        let model = zoo::transducer(0);
        let sched = MensaScheduler::new(&sys).schedule(&model);
        let mensa = sim.run(&model, &sched);
        for a in 0..sys.len() {
            let fixed = sim.run(&model, &Mapping::uniform(model.len(), a));
            assert!(
                mensa.total_latency_s <= fixed.total_latency_s * 1.05,
                "scheduled {} vs all-on-{} {}",
                mensa.total_latency_s,
                sys.accels[a].name,
                fixed.total_latency_s
            );
        }
    }

    #[test]
    fn oracle_no_worse_than_heuristic() {
        let sys = configs::mensa_g();
        let sim = Simulator::new(&sys);
        let lambda = 1e3; // ~balance seconds and joules at edge scales
        for model in [zoo::cnn(4), zoo::lstm(2)] {
            let heuristic = MensaScheduler::new(&sys).schedule(&model);
            let orc = oracle(&sys, &model, lambda);
            let score = |m: &Mapping| {
                let r = sim.run(&model, m);
                r.total_latency_s + lambda * r.total_energy_j()
            };
            let h = score(&heuristic);
            let o = score(&orc);
            // DP approximates skip-edge transfers, so allow 5% slack.
            assert!(o <= h * 1.05, "{}: oracle {o} vs heuristic {h}", model.name);
        }
    }

    #[test]
    fn schedules_have_few_switches_like_the_paper() {
        // §5.6: models typically communicate between accelerators only
        // 4-5 times during execution (CNN5-7 more, due to skips).
        let sys = configs::mensa_g();
        for model in zoo::all() {
            let m = MensaScheduler::new(&sys).schedule(&model);
            assert!(
                m.switch_count() <= 16,
                "{}: {} switches",
                model.name,
                m.switch_count()
            );
        }
    }
}
