//! # Mensa: heterogeneous edge ML inference acceleration
//!
//! A from-scratch reproduction of *"Google Neural Network Models for Edge
//! Devices: Analyzing and Mitigating Machine Learning Inference
//! Bottlenecks"* (Boroumand et al., 2021) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the Mensa coordinator: NN graph IR and a
//!   24-model edge zoo ([`model`]), per-layer characterization and the
//!   five-family taxonomy ([`characterize`]), accelerator hardware and
//!   dataflow cost models ([`accel`]), a CACTI-calibrated energy model
//!   ([`energy`]), an execution simulator ([`sim`]), the two-phase Mensa
//!   runtime scheduler ([`scheduler`]), throughput/energy rooflines
//!   ([`roofline`]), a PJRT artifact runtime ([`runtime`]), and a
//!   multi-threaded serving coordinator ([`coordinator`]).
//! * **Layer 2** — JAX model definitions (`python/compile/model.py`),
//!   AOT-lowered to HLO text consumed by [`runtime`].
//! * **Layer 1** — Pallas kernels implementing the Pascal / Pavlov /
//!   Jacquard dataflows (`python/compile/kernels/`).
//!
//! Python never runs on the request path: `make artifacts` lowers the
//! models once, and the Rust binary is self-contained afterwards.
//!
//! ## Quick start
//!
//! ```
//! use mensa::model::zoo;
//! use mensa::accel::configs;
//! use mensa::scheduler::MensaScheduler;
//! use mensa::sim::Simulator;
//!
//! let model = zoo::cnn(0); // CNN1
//! let system = configs::mensa_g();
//! let mapping = MensaScheduler::new(&system).schedule(&model);
//! let report = Simulator::new(&system).run(&model, &mapping);
//! assert!(report.total_latency_s > 0.0);
//! assert!(report.total_energy_j() > 0.0);
//! ```

pub mod accel;
pub mod bench_harness;
pub mod characterize;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod model;
pub mod roofline;
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
