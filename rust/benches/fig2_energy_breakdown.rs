//! `cargo bench --bench fig2_energy_breakdown` — regenerates the Fig. 2 energy breakdown
//! and times the underlying computation (criterion is unavailable
//! offline; see bench_harness::timer).

use mensa::bench_harness::{run_experiment, timer};

fn main() {
    timer::header("fig2_energy_breakdown");
    for id in ["fig2"] {
        let report = run_experiment(id).expect("experiment");
        println!("{report}");
        let m = timer::bench(id, 5, 2, || {
            std::hint::black_box(run_experiment(id).unwrap());
        });
        println!("{}", m.render());
    }
}
