//! `cargo bench --bench fig11_util_throughput` — regenerates Fig. 11 (utilization + normalized throughput)
//! and times the underlying computation (criterion is unavailable
//! offline; see bench_harness::timer).

use mensa::bench_harness::{run_experiment, timer};

fn main() {
    timer::header("fig11_util_throughput");
    for id in ["fig11-util", "fig11-tput"] {
        let report = run_experiment(id).expect("experiment");
        println!("{report}");
        let m = timer::bench(id, 5, 2, || {
            std::hint::black_box(run_experiment(id).unwrap());
        });
        println!("{}", m.render());
    }
}
