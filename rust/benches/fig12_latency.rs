//! `cargo bench --bench fig12_latency` — regenerates Fig. 12 (normalized latency + accelerator split)
//! and times the underlying computation (criterion is unavailable
//! offline; see bench_harness::timer).

use mensa::bench_harness::{run_experiment, timer};

fn main() {
    timer::header("fig12_latency");
    for id in ["fig12"] {
        let report = run_experiment(id).expect("experiment");
        println!("{report}");
        let m = timer::bench(id, 5, 2, || {
            std::hint::black_box(run_experiment(id).unwrap());
        });
        println!("{}", m.render());
    }
}
