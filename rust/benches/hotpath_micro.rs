//! `cargo bench --bench hotpath_micro` — L3 hot-path microbenchmarks
//! for the §Perf optimization pass (EXPERIMENTS.md), plus the
//! **serving-throughput benchmark** for the work-stealing executor
//! pool, whose results are written to `BENCH_serving.json` at the
//! repository root (overwritten per run; commit or archive it to
//! build the perf trajectory over time).
//!
//! Serving methodology: a synthetic artifact set of 8 dense families
//! is generated into a temp directory with family names chosen (by
//! scanning the real FNV hash) to all collide onto worker 0 of a
//! 4-worker pool — the deterministic worst case that *any* fixed
//! hash suffers once families outnumber workers (pigeonhole), and the
//! exact pathology the paper attributes to one-size-fits-all
//! assignment. Three load cases run against both routing modes:
//!
//! * `skewed_device_emulated` — one hot family (~30% of requests),
//!   per-job emulated device busy time (the hardware-in-the-loop
//!   stand-in for each family's edge accelerator). This is the
//!   headline ≥2x case: static routing serializes every family's
//!   device window behind one worker, stealing overlaps them, so the
//!   gap scales with worker count rather than host core count.
//! * `skewed_cpu_bound` — same skew, no emulation: the gain is then
//!   bounded by host cores (informational on small CI machines).
//! * `uniform_cpu_bound` — no skew, no emulation.
//!
//! A kernel microbenchmark (naive scan vs blocked/transposed
//! zero-alloc) over the real `edge_cnn_b8` artifact rides along.

use mensa::accel::configs;
use mensa::bench_harness::timer;
use mensa::config::ServerConfig;
use mensa::coordinator::{worker_for_family, Server};
use mensa::model::zoo;
use mensa::runtime::{ExecScratch, Runtime, RuntimeOptions};
use mensa::scheduler::{Mapping, MensaScheduler, ScheduleCache};
use mensa::sim::Simulator;
use std::fmt::Write as _;
use std::hint::black_box;
use std::time::{Duration, Instant};

/// Synthetic dense-family geometry: ~0.6 MMAC per sample keeps a
/// batch-8 job in the hundreds of microseconds, large vs dispatch.
const BENCH_IN: usize = 1536;
const BENCH_OUT: usize = 384;
const BENCH_WORKERS: usize = 4;
const BENCH_FAMILIES: usize = 8;
const BENCH_REQUESTS: usize = 1600;
const BENCH_DEVICE_US: u64 = 1000;

fn main() {
    timer::header("hotpath_micro");
    let baseline = configs::edge_tpu_baseline();
    let mensa = configs::mensa_g();
    let cnn = zoo::cnn(0);
    let lstm = zoo::lstm(0);

    // 1. Dataflow cost model, per layer (the innermost hot function).
    let layer = &cnn.layers()[5];
    let m = timer::bench("dataflow_cost/conv_layer", 20, 10_000, || {
        black_box(baseline.dataflow.cost(&baseline, black_box(layer)));
    });
    println!("{}", m.render());
    let gate = lstm
        .layers()
        .iter()
        .find(|l| l.name.contains("gate"))
        .expect("lstm gate");
    let m = timer::bench("dataflow_cost/lstm_gate", 20, 10_000, || {
        black_box(mensa.accels[1].dataflow.cost(&mensa.accels[1], black_box(gate)));
    });
    println!("{}", m.render());

    // 2. Scheduler: full two-phase schedule of one model.
    let scheduler = MensaScheduler::new(&mensa);
    let m = timer::bench("scheduler/cnn_schedule", 10, 200, || {
        black_box(scheduler.schedule(black_box(&cnn)));
    });
    println!("{}", m.render());
    let m = timer::bench("scheduler/lstm_schedule", 10, 200, || {
        black_box(scheduler.schedule(black_box(&lstm)));
    });
    println!("{}", m.render());

    // 3. Simulator: one inference end to end.
    let sim = Simulator::new(&mensa);
    let mapping = scheduler.schedule(&cnn);
    let m = timer::bench("simulator/cnn_run", 10, 200, || {
        black_box(sim.run(black_box(&cnn), black_box(&mapping)));
    });
    println!("{}", m.render());
    let base_sys = configs::baseline_system();
    let base_sim = Simulator::new(&base_sys);
    let base_map = Mapping::uniform(lstm.len(), 0);
    let m = timer::bench("simulator/lstm_run_baseline", 10, 200, || {
        black_box(base_sim.run(black_box(&lstm), black_box(&base_map)));
    });
    println!("{}", m.render());

    // 4. ScheduleCache: the serving path's family_sim_costs()
    // equivalent — cold (schedule + simulate) vs a warm cache hit
    // (structural hash + read lock + Arc clone). Acceptance bar: the
    // hit must be >= 10x faster than the cold path.
    let cold = timer::bench("schedule_cache/cold_miss", 5, 5, || {
        let cache = ScheduleCache::new();
        black_box(cache.get_or_compute(black_box(&mensa), black_box(&cnn)));
    });
    println!("{}", cold.render());
    let warm_cache = ScheduleCache::new();
    warm_cache.get_or_compute(&mensa, &cnn);
    let warm = timer::bench("schedule_cache/warm_hit", 20, 2_000, || {
        black_box(warm_cache.get_or_compute(black_box(&mensa), black_box(&cnn)));
    });
    println!("{}", warm.render());
    println!(
        "schedule_cache speedup: {:.0}x (cold {:.0} ns -> hit {:.0} ns)",
        cold.mean_ns / warm.mean_ns.max(1.0),
        cold.mean_ns,
        warm.mean_ns
    );

    // 5. Reference-kernel microbench over the real edge_cnn_b8
    // artifact: PR-1 naive scan layout (throwaway scratch per call) vs
    // the blocked/transposed kernel with reused scratch.
    let kernel = bench_kernels();

    // 6. Serving throughput: work-stealing pool vs the static
    // family-hash baseline under skewed and uniform loads.
    let serving = bench_serving();

    write_bench_json(&kernel, &serving);

    // 7. Macro: the full 24-model x 4-system evaluation grid.
    let m = timer::bench("grid/24x4_evaluation", 3, 2, || {
        black_box(mensa::bench_harness::evaluation::evaluation_grid());
    });
    println!("{}", m.render());
}

/// Naive-vs-blocked kernel timing, ns per sample.
struct KernelResult {
    naive_ns_per_sample: f64,
    blocked_ns_per_sample: f64,
}

fn bench_kernels() -> KernelResult {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let fast = Runtime::load(dir).expect("runtime");
    let naive =
        Runtime::load_with(dir, RuntimeOptions { naive_kernels: true }).expect("runtime");
    let model_fast = fast.model("edge_cnn_b8").expect("edge_cnn_b8");
    let model_naive = naive.model("edge_cnn_b8").expect("edge_cnn_b8");
    let input: Vec<f32> = (0..8 * 32 * 32 * 3).map(|i| ((i % 17) as f32 - 8.0) / 17.0).collect();
    let inputs = vec![input];
    let mut scratch = ExecScratch::default();
    let blocked = timer::bench("ref_kernel/blocked_transposed_b8", 10, 200, || {
        black_box(model_fast.execute_with(black_box(&inputs), 8, &mut scratch).unwrap());
    });
    println!("{}", blocked.render());
    let naive_m = timer::bench("ref_kernel/naive_scan_b8", 10, 200, || {
        black_box(model_naive.execute(black_box(&inputs)).unwrap());
    });
    println!("{}", naive_m.render());
    println!(
        "ref kernel speedup (b8, per sample): {:.2}x (naive {:.0} ns -> blocked {:.0} ns)",
        naive_m.mean_ns / blocked.mean_ns.max(1.0),
        naive_m.mean_ns / 8.0,
        blocked.mean_ns / 8.0
    );
    KernelResult {
        naive_ns_per_sample: naive_m.mean_ns / 8.0,
        blocked_ns_per_sample: blocked.mean_ns / 8.0,
    }
}

/// One routing comparison: (static_rps, stealing_rps).
struct CaseResult {
    name: &'static str,
    static_rps: f64,
    stealing_rps: f64,
}

impl CaseResult {
    fn speedup(&self) -> f64 {
        self.stealing_rps / self.static_rps.max(1e-9)
    }
}

struct ServingResult {
    cases: Vec<CaseResult>,
}

/// Family names that all hash to worker 0 of a `BENCH_WORKERS` pool —
/// the deterministic static-routing worst case (always constructible:
/// with more families than workers, some worker hosts several; we pin
/// the set so the measurement is reproducible).
fn colliding_families() -> Vec<String> {
    let mut fams = Vec::new();
    let mut i = 0usize;
    while fams.len() < BENCH_FAMILIES {
        let name = format!("fam{i:03}");
        if worker_for_family(&name, BENCH_WORKERS) == 0 {
            fams.push(name);
        }
        i += 1;
    }
    fams
}

/// Write the synthetic benchmark artifact manifest (dense families,
/// variants b1/b4/b8, reference backend — no HLO files needed).
fn write_bench_artifacts(families: &[String]) -> String {
    // Per-process dir: concurrent runs (or different users on one
    // machine) must not race on the manifest.
    let dir =
        std::env::temp_dir().join(format!("mensa_bench_artifacts_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create bench artifacts dir");
    let mut manifest = String::from("# Generated by hotpath_micro — synthetic serving families.\n");
    for family in families {
        for b in [1usize, 4, 8] {
            let _ = write!(
                manifest,
                "\n[[artifact]]\nname = \"{family}_b{b}\"\nfile = \"{family}_b{b}.hlo.txt\"\n\
                 num_inputs = 1\ninput0_shape = \"{b}x{BENCH_IN}\"\ninput0_batch_axis = 0\n\
                 output_shape = \"{b}x{BENCH_OUT}\"\noutput_batch_axis = 0\n\
                 sha256 = \"referencebackend\"\n"
            );
        }
    }
    std::fs::write(dir.join("manifest.toml"), manifest).expect("write bench manifest");
    dir.to_str().expect("utf8 temp dir").to_string()
}

/// Deterministic 20-slot request pattern: index 0 is the hot family
/// (6/20 = 30%), the rest spread evenly.
const SKEW_PATTERN: [usize; 20] = [0, 1, 2, 0, 3, 4, 0, 5, 6, 0, 7, 1, 0, 2, 3, 0, 4, 5, 6, 7];

/// Run one serving case; returns completed requests per second.
fn run_case(dir: &str, families: &[String], stealing: bool, skewed: bool, device_us: u64) -> f64 {
    let cfg = ServerConfig {
        workers: BENCH_WORKERS,
        max_batch: 8,
        batch_timeout_us: 300,
        queue_depth: 2 * BENCH_REQUESTS,
        work_stealing: stealing,
        // One shard in BOTH modes: the comparison isolates the routing
        // discipline (sharding is a separate axis, and the colliding
        // family set would all land on shard 0 anyway).
        batcher_shards: 1,
        naive_kernels: false,
        device_latency_us: device_us,
    };
    let server = Server::start(dir, cfg).expect("bench server start");
    let input: Vec<f32> = (0..BENCH_IN).map(|i| ((i % 23) as f32 - 11.0) / 23.0).collect();
    let t0 = Instant::now();
    let mut rxs = Vec::with_capacity(BENCH_REQUESTS);
    for k in 0..BENCH_REQUESTS {
        let fam_idx = if skewed { SKEW_PATTERN[k % SKEW_PATTERN.len()] } else { k % families.len() };
        let family = &families[fam_idx];
        // Retry backpressure rejections, but fail fast (instead of
        // hanging CI) if the server has actually died.
        let deadline = Instant::now() + Duration::from_secs(120);
        loop {
            match server.infer(family, vec![input.clone()]) {
                Ok(rx) => {
                    rxs.push(rx);
                    break;
                }
                Err(e) => {
                    assert!(
                        Instant::now() < deadline,
                        "bench submission stalled for 120s (server dead?): {e:#}"
                    );
                    std::thread::sleep(Duration::from_micros(200));
                }
            }
        }
    }
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(120)).expect("bench recv").expect("bench ok");
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = server.metrics();
    assert_eq!(snap.fifo_violations, 0, "bench load must stay FIFO");
    server.shutdown();
    BENCH_REQUESTS as f64 / wall
}

fn bench_serving() -> ServingResult {
    timer::header("serving_throughput");
    let families = colliding_families();
    let dir = write_bench_artifacts(&families);
    println!(
        "synthetic families (all statically pinned to worker 0 of {BENCH_WORKERS}): {families:?}"
    );
    let mut cases = Vec::new();
    for (name, skewed, device_us) in [
        ("skewed_device_emulated", true, BENCH_DEVICE_US),
        ("skewed_cpu_bound", true, 0),
        ("uniform_cpu_bound", false, 0),
    ] {
        let static_rps = run_case(&dir, &families, false, skewed, device_us);
        let stealing_rps = run_case(&dir, &families, true, skewed, device_us);
        let case = CaseResult { name, static_rps, stealing_rps };
        println!(
            "{name:<24} static {static_rps:>9.0} req/s | stealing {stealing_rps:>9.0} req/s | \
             speedup {:.2}x",
            case.speedup()
        );
        cases.push(case);
    }
    let headline = &cases[0];
    if headline.speedup() >= 2.0 {
        println!(
            "PASS: skewed-load stealing speedup {:.2}x >= 2x on {BENCH_WORKERS} workers",
            headline.speedup()
        );
    } else {
        println!(
            "WARN: skewed-load stealing speedup {:.2}x < 2x (host has few cores? see \
             skewed_device_emulated notes)",
            headline.speedup()
        );
    }
    ServingResult { cases }
}

fn write_bench_json(kernel: &KernelResult, serving: &ServingResult) {
    let mut json = String::from("{\n  \"bench\": \"serving_throughput\",\n");
    let _ = write!(
        json,
        "  \"workers\": {BENCH_WORKERS},\n  \"families\": {BENCH_FAMILIES},\n  \
         \"requests\": {BENCH_REQUESTS},\n"
    );
    for case in &serving.cases {
        let _ = write!(
            json,
            "  \"{}\": {{\"static_rps\": {:.1}, \"stealing_rps\": {:.1}, \"speedup\": {:.3}}},\n",
            case.name,
            case.static_rps,
            case.stealing_rps,
            case.speedup()
        );
    }
    let _ = write!(
        json,
        "  \"kernel_dense\": {{\"naive_ns_per_sample\": {:.1}, \"blocked_ns_per_sample\": {:.1}, \
         \"speedup\": {:.3}}}\n}}\n",
        kernel.naive_ns_per_sample,
        kernel.blocked_ns_per_sample,
        kernel.naive_ns_per_sample / kernel.blocked_ns_per_sample.max(1e-9)
    );
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serving.json");
    std::fs::write(path, &json).expect("write BENCH_serving.json");
    println!("wrote {path}");
}
