//! `cargo bench --bench hotpath_micro` — L3 hot-path microbenchmarks
//! for the §Perf optimization pass (EXPERIMENTS.md), plus the
//! **serving-throughput benchmark** for the work-stealing executor
//! pool, whose results are written to `BENCH_serving.json` at the
//! repository root (overwritten per run; commit or archive it to
//! build the perf trajectory over time).
//!
//! Serving methodology: a synthetic artifact set of 8 dense families
//! is generated into a temp directory with family names chosen (by
//! scanning the real FNV hash) to all collide onto worker 0 of a
//! 4-worker pool — the deterministic worst case that *any* fixed
//! hash suffers once families outnumber workers (pigeonhole), and the
//! exact pathology the paper attributes to one-size-fits-all
//! assignment. Load cases:
//!
//! * `skewed_device_emulated` — one hot family (~30% of requests),
//!   per-job emulated device busy time (the hardware-in-the-loop
//!   stand-in for each family's edge accelerator), static vs stealing
//!   routing. This is the headline ≥2x case: static routing serializes
//!   every family's device window behind one worker, stealing overlaps
//!   them, so the gap scales with worker count rather than host cores.
//! * `skewed_cpu_bound` / `uniform_cpu_bound` — no emulation; the
//!   routing gain is then bounded by host cores (informational on
//!   small CI machines).
//! * `skewed_gemm` — same skewed load, stealing both sides, comparing
//!   **batched GEMM vs per-sample** execution (PR 3's tentpole): the
//!   batched path streams each weight tile once per column block
//!   instead of once per sample, so at executed batches ≥ 4 its
//!   throughput must beat the per-sample baseline.
//! * `hot_family_reorder` — 100% of requests on ONE family with
//!   device emulation, comparing the **family lease vs the reorder
//!   buffer** (`reorder_depth = workers`): the lease serializes the
//!   hot family's jobs on one worker at a time, the reorder buffer
//!   fans them across the pool while `fifo_violations` stays 0
//!   (asserted per run).
//! * `oversized_job_chunks` — closed-loop bursts of 32 requests on one
//!   family (`max_batch = 32`, variants top out at b8), so exactly one
//!   four-chunk job is in flight at a time, with per-chunk device
//!   emulation. **Job-granular vs chunk-granular** sequencing
//!   (`chunk_level`): job-granular runs the four chunks front-to-back
//!   on one worker (4 serial device windows per burst); chunk-granular
//!   spreads them across the pool (PR 4's tentpole).
//! * `adaptive_depth` — shifting 100% skew (the hot family alternates
//!   each quarter of the run) with device emulation, comparing the
//!   **static lease vs adaptive per-family depth**
//!   (`reorder_depth_max = workers`): the adaptive policy widens
//!   whichever family is currently backlogged, without a hand-tuned
//!   static `reorder_depth`.
//! * `mensa_placement` — the same skewed mix on two `[[device]]`
//!   rosters of equal worker count: a **homogeneous pool** (three
//!   Edge-TPU-baseline workers) vs the **Mensa heterogeneous pool**
//!   (Pascal + Pavlov + Jacquard, one worker each) with
//!   placement-aware dispatch. Both arms share one calibrated
//!   `latency_scale`, so the only difference is *which class's
//!   emulated window each family pays* — the paper's Mensa claim
//!   (bandwidth-starved families on the HBM classes, compute-bound
//!   ones on Pascal) as a serving A/B.
//! * `overload_goodput` — PR 7's tentpole A/B: one family offered
//!   ~4x its emulated service capacity in bursty open-loop arrivals,
//!   every request on a fixed deadline, `overload = "block"` vs
//!   `"shed"`. Blocking answers everything eventually but queues blow
//!   almost every budget; admission + enqueue shedding keeps queues
//!   short so the requests that ARE served land inside their budgets.
//!   Reported per arm: SLO attainment (in-budget fraction of the full
//!   *offered* load) and goodput (in-budget responses per second),
//!   plus their block→shed ratio (`slo_gain`).
//! * `hier_escalation` — hierarchical inference: every request sent
//!   straight to the large variant vs small-first with
//!   confidence-gated escalation (`escalate_to`). The threshold is
//!   pinned at the probed median confidence of the small variant over
//!   the exact bench inputs, so ~half the requests escalate by
//!   construction; the small pass costs ~1/16th of the large one, so
//!   hierarchical serving pays roughly half the MACs.
//! * `degraded_failover` — PR 8's fault-tolerance A/B/C: one family on
//!   a calibrated two-class `[[device]]` roster, arrivals paced at
//!   ~70% of the BACKUP class's service capacity. Healthy roster vs
//!   "placed class blacked out, budget-aware retry + circuit-breaker
//!   failover armed" vs the same blackout with recovery disabled
//!   (`retry_max = 0`, `breaker_threshold = 0`). The breaker re-places
//!   the family on the backup class, so the failover arm must RETAIN
//!   most of the healthy goodput (`retention`); the bare arm fails
//!   every placed request, so `retention_gain` (failover retention
//!   over bare retention, saturated at ~25x) shows what the recovery
//!   ladder buys.
//! * `layer_pipeline` — PR 9's tentpole A/B: a single hot multi-stage
//!   family (`edge_rcnn`, four dense stages, proxied by the zoo's
//!   mixed CNN-front/LSTM-back RCNN1) under the family-lease
//!   discipline (`reorder_depth = 0`), monolithic vs segmented
//!   (`segment_level`, `max_segments = 4`). The lease pins the
//!   monolithic stream's chunks to one worker at a time; segmentation
//!   cuts each chunk into profiled per-layer segments whose
//!   continuation lanes (`edge_rcnn@s`) each hold their own lease, so
//!   the SAME strictly-FIFO stream pipelines across workers —
//!   `fifo_violations` stays 0 and every response is bit-exact vs the
//!   monolithic arm. A third leg serves the segmented stream on a
//!   calibrated Pascal + Pavlov roster: segments land on their
//!   modeled-argmin classes (≥ 2 classes execute) and every class
//!   boundary charges an activation-transfer window
//!   (`cross_device_transfers > 0`), still bit-exact.
//!
//! Kernel microbenchmarks ride along: naive scan vs blocked/transposed
//! (real `edge_cnn_b8`), per-sample vs batched GEMM (synthetic
//! heavy-weight family, where parameter streaming dominates), and the
//! PR 5 pair —
//!
//! * `packed_panels` — scalar kernels both sides, **row-major
//!   transposed vs panel-major prepacked** weight layout: the packed
//!   walk is one sequential stream with `x[k]` loaded once per 8 rows
//!   instead of once per 4, so it must beat the row-major baseline at
//!   identical (bit-for-bit) numerics.
//! * `simd_kernel` — packed layout both sides, **portable scalar vs
//!   the runtime-dispatched explicit AVX2+FMA microkernel**. On hosts
//!   without AVX2 the dispatch falls back to scalar and the speedup
//!   reports ~1.0 (a WARN is printed; the CI gate runs on AVX2
//!   runners).

use mensa::accel::configs;
use mensa::bench_harness::timer;
use mensa::config::{DeviceClass, DeviceClassSpec, FamilyPolicy, OverloadPolicy, ServerConfig};
use mensa::coordinator::{device, worker_for_family, Server};
use mensa::model::zoo;
use mensa::runtime::{
    simd_kernel_available, ExecScratch, FaultPlan, KernelKind, Precision, Runtime, RuntimeOptions,
};
use mensa::scheduler::{Mapping, MensaScheduler, ScheduleCache};
use mensa::sim::Simulator;
use mensa::util::rng::Rng;
use std::fmt::Write as _;
use std::hint::black_box;
use std::time::{Duration, Instant};

/// Synthetic dense-family geometry: ~0.6 MMAC per sample keeps a
/// batch-8 job in the hundreds of microseconds, large vs dispatch, and
/// the ~2.4 MB weight matrix makes parameter streaming the dominant
/// cost — the regime the batched GEMM targets.
const BENCH_IN: usize = 1536;
const BENCH_OUT: usize = 384;
const BENCH_WORKERS: usize = 4;
const BENCH_FAMILIES: usize = 8;
const BENCH_REQUESTS: usize = 1600;
const BENCH_DEVICE_US: u64 = 1000;
/// Overload A/B: one family whose 1 ms emulated window caps the pool
/// at `BENCH_WORKERS` req/ms; bursts average ~15 req/ms (~4x).
const OVERLOAD_REQUESTS: usize = 600;
const OVERLOAD_DEADLINE_US: u64 = 6_000;
/// Hierarchical-escalation A/B: a small/large variant pair sharing
/// the `BENCH_IN` input; the 64 vs 1024 output width makes the small
/// pass ~1/16th of the large one's MACs.
const ESC_REQUESTS: usize = 256;
const ESC_SMALL_OUT: usize = 64;
const ESC_LARGE_OUT: usize = 1024;
/// Degraded-failover A/B/C: arrivals are paced (one
/// `FAILOVER_BURST`-sized burst per `FAILOVER_BURST` ms ≈ 1 req/ms)
/// and the roster's shared `latency_scale` is calibrated so the
/// SLOWEST class serves the load family in `FAILOVER_DEVICE_US` — the
/// backup class alone sustains the offered load at ~70% utilization,
/// so goodput retention measures recovery, not capacity starvation.
const FAILOVER_REQUESTS: usize = 240;
const FAILOVER_BURST: usize = 12;
const FAILOVER_DEVICE_US: u64 = 700;
/// Layer-pipeline A/B: the `edge_rcnn` family carries `PIPE_STAGES`
/// dense input blocks, so its reference variants expose that many
/// runtime stages for `segment_level` to cut (`max_segments` is set
/// to the same value). 640 open-loop requests coalesce into ~80
/// eight-row chunks — enough for the pipeline's steady state to
/// dominate its fill/drain ramps.
const PIPE_REQUESTS: usize = 640;
const PIPE_STAGES: usize = 4;
/// Quantized A/B: the recurrent leg's `edge_lstm` bench entry —
/// `QLSTM_T` timesteps over a `QLSTM_D`-wide state, so each step
/// streams two `QLSTM_D`²-element gate matrices (f32: ~512 KB total;
/// i8: ~128 KB) through the same packed-panel kernels as the dense
/// leg.
const QLSTM_T: usize = 8;
const QLSTM_D: usize = 256;

fn main() {
    timer::header("hotpath_micro");
    let baseline = configs::edge_tpu_baseline();
    let mensa = configs::mensa_g();
    let cnn = zoo::cnn(0);
    let lstm = zoo::lstm(0);

    // 1. Dataflow cost model, per layer (the innermost hot function).
    let layer = &cnn.layers()[5];
    let m = timer::bench("dataflow_cost/conv_layer", 20, 10_000, || {
        black_box(baseline.dataflow.cost(&baseline, black_box(layer)));
    });
    println!("{}", m.render());
    let gate = lstm
        .layers()
        .iter()
        .find(|l| l.name.contains("gate"))
        .expect("lstm gate");
    let m = timer::bench("dataflow_cost/lstm_gate", 20, 10_000, || {
        black_box(mensa.accels[1].dataflow.cost(&mensa.accels[1], black_box(gate)));
    });
    println!("{}", m.render());

    // 2. Scheduler: full two-phase schedule of one model.
    let scheduler = MensaScheduler::new(&mensa);
    let m = timer::bench("scheduler/cnn_schedule", 10, 200, || {
        black_box(scheduler.schedule(black_box(&cnn)));
    });
    println!("{}", m.render());
    let m = timer::bench("scheduler/lstm_schedule", 10, 200, || {
        black_box(scheduler.schedule(black_box(&lstm)));
    });
    println!("{}", m.render());

    // 3. Simulator: one inference end to end.
    let sim = Simulator::new(&mensa);
    let mapping = scheduler.schedule(&cnn);
    let m = timer::bench("simulator/cnn_run", 10, 200, || {
        black_box(sim.run(black_box(&cnn), black_box(&mapping)));
    });
    println!("{}", m.render());
    let base_sys = configs::baseline_system();
    let base_sim = Simulator::new(&base_sys);
    let base_map = Mapping::uniform(lstm.len(), 0);
    let m = timer::bench("simulator/lstm_run_baseline", 10, 200, || {
        black_box(base_sim.run(black_box(&lstm), black_box(&base_map)));
    });
    println!("{}", m.render());

    // 4. ScheduleCache: the serving path's family_sim_costs()
    // equivalent — cold (schedule + simulate) vs a warm cache hit
    // (structural hash + read lock + Arc clone). Acceptance bar: the
    // hit must be >= 10x faster than the cold path.
    let cold = timer::bench("schedule_cache/cold_miss", 5, 5, || {
        let cache = ScheduleCache::new();
        black_box(cache.get_or_compute(black_box(&mensa), black_box(&cnn)));
    });
    println!("{}", cold.render());
    let warm_cache = ScheduleCache::new();
    warm_cache.get_or_compute(&mensa, &cnn);
    let warm = timer::bench("schedule_cache/warm_hit", 20, 2_000, || {
        black_box(warm_cache.get_or_compute(black_box(&mensa), black_box(&cnn)));
    });
    println!("{}", warm.render());
    println!(
        "schedule_cache speedup: {:.0}x (cold {:.0} ns -> hit {:.0} ns)",
        cold.mean_ns / warm.mean_ns.max(1.0),
        cold.mean_ns,
        warm.mean_ns
    );

    // Shared synthetic serving artifacts (also the GEMM microbench
    // substrate — its weight matrices dwarf the real edge_cnn's).
    let families = colliding_families();
    let bench_dir = write_bench_artifacts(&families);

    // 5. Reference-kernel microbenches: PR-1 naive scan vs blocked
    // kernels (real edge_cnn_b8), per-sample vs batched GEMM
    // (synthetic heavy-weight b8), row-major vs packed panels, and
    // scalar vs the explicit-SIMD microkernel.
    let kernel = bench_kernels();
    let gemm = bench_gemm_kernel(&bench_dir);
    let packed = bench_packed_panels(&bench_dir);
    let simd = bench_simd_kernel(&bench_dir);
    let quant = bench_quantized_gemm(&bench_dir);

    // 6. Serving throughput: routing, kernel, and ordering-discipline
    // comparisons under skewed / uniform / hot-family loads.
    let serving = bench_serving(&bench_dir, &families);

    write_bench_json(&kernel, &gemm, &packed, &simd, &quant, &serving);

    // 7. Macro: the full 24-model x 4-system evaluation grid.
    let m = timer::bench("grid/24x4_evaluation", 3, 2, || {
        black_box(mensa::bench_harness::evaluation::evaluation_grid());
    });
    println!("{}", m.render());
}

/// Naive-vs-blocked kernel timing, ns per sample.
struct KernelResult {
    naive_ns_per_sample: f64,
    blocked_ns_per_sample: f64,
}

fn bench_kernels() -> KernelResult {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let fast = Runtime::load(dir).expect("runtime");
    let naive = Runtime::load_with(
        dir,
        RuntimeOptions { naive_kernels: true, ..Default::default() },
    )
    .expect("runtime");
    let model_fast = fast.model("edge_cnn_b8").expect("edge_cnn_b8");
    let model_naive = naive.model("edge_cnn_b8").expect("edge_cnn_b8");
    let input: Vec<f32> = (0..8 * 32 * 32 * 3).map(|i| ((i % 17) as f32 - 8.0) / 17.0).collect();
    let inputs = vec![input];
    let mut scratch = ExecScratch::default();
    let blocked = timer::bench("ref_kernel/blocked_transposed_b8", 10, 200, || {
        black_box(model_fast.execute_with(black_box(&inputs), 8, &mut scratch).unwrap());
    });
    println!("{}", blocked.render());
    let naive_m = timer::bench("ref_kernel/naive_scan_b8", 10, 200, || {
        black_box(model_naive.execute(black_box(&inputs)).unwrap());
    });
    println!("{}", naive_m.render());
    println!(
        "ref kernel speedup (b8, per sample): {:.2}x (naive {:.0} ns -> blocked {:.0} ns)",
        naive_m.mean_ns / blocked.mean_ns.max(1.0),
        naive_m.mean_ns / 8.0,
        blocked.mean_ns / 8.0
    );
    KernelResult {
        naive_ns_per_sample: naive_m.mean_ns / 8.0,
        blocked_ns_per_sample: blocked.mean_ns / 8.0,
    }
}

/// Per-sample vs batched-GEMM timing over the synthetic heavy-weight
/// family (weights ~2.4 MB: parameter streaming dominates, so the
/// once-per-column-block amortization is what's measured).
struct GemmResult {
    per_sample_ns_per_sample: f64,
    batched_ns_per_sample: f64,
}

fn bench_gemm_kernel(dir: &str) -> GemmResult {
    let batched = Runtime::load(dir).expect("bench runtime");
    let per_sample = Runtime::load_with(
        dir,
        RuntimeOptions { batched_gemm: false, ..Default::default() },
    )
    .expect("bench runtime");
    let name = "fam000_b8";
    let mb = batched.model(name).expect("bench b8 variant");
    let mp = per_sample.model(name).expect("bench b8 variant");
    let input: Vec<f32> =
        (0..8 * BENCH_IN).map(|i| ((i % 23) as f32 - 11.0) / 23.0).collect();
    let inputs = vec![input];
    let mut scratch = ExecScratch::default();
    let b = timer::bench("ref_kernel/gemm_batched_b8", 10, 100, || {
        black_box(mb.execute_with(black_box(&inputs), 8, &mut scratch).unwrap());
    });
    println!("{}", b.render());
    let p = timer::bench("ref_kernel/gemm_per_sample_b8", 10, 100, || {
        black_box(mp.execute_with(black_box(&inputs), 8, &mut scratch).unwrap());
    });
    println!("{}", p.render());
    println!(
        "batched GEMM speedup (b8, per sample): {:.2}x (per-sample {:.0} ns -> batched {:.0} ns)",
        p.mean_ns / b.mean_ns.max(1.0),
        p.mean_ns / 8.0,
        b.mean_ns / 8.0
    );
    GemmResult {
        per_sample_ns_per_sample: p.mean_ns / 8.0,
        batched_ns_per_sample: b.mean_ns / 8.0,
    }
}

/// One kernel-micro A/B over the synthetic heavy-weight b8 variant:
/// baseline vs treatment `RuntimeOptions`, ns per sample.
fn bench_kernel_ab(
    dir: &str,
    label: (&str, &str),
    baseline_opts: RuntimeOptions,
    treatment_opts: RuntimeOptions,
) -> (f64, f64) {
    let baseline = Runtime::load_with(dir, baseline_opts).expect("bench runtime");
    let treatment = Runtime::load_with(dir, treatment_opts).expect("bench runtime");
    (
        bench_model_ns_per_sample(&baseline, "fam000_b8", 8 * BENCH_IN, label.0),
        bench_model_ns_per_sample(&treatment, "fam000_b8", 8 * BENCH_IN, label.1),
    )
}

/// Time one b8 variant on an already-loaded runtime, ns per sample.
fn bench_model_ns_per_sample(rt: &Runtime, name: &str, in_elems: usize, label: &str) -> f64 {
    let model = rt.model(name).expect("bench b8 variant");
    let input: Vec<f32> =
        (0..in_elems).map(|i| ((i % 23) as f32 - 11.0) / 23.0).collect();
    let inputs = vec![input];
    let mut scratch = ExecScratch::default();
    let m = timer::bench(label, 10, 100, || {
        black_box(model.execute_with(black_box(&inputs), 8, &mut scratch).unwrap());
    });
    println!("{}", m.render());
    m.mean_ns / 8.0
}

/// Row-major vs panel-major weight layout, scalar kernels both sides
/// (the layouts are bit-identical, so this isolates the memory-walk
/// effect of the prepack).
struct PackedResult {
    row_major_ns_per_sample: f64,
    packed_ns_per_sample: f64,
}

fn bench_packed_panels(dir: &str) -> PackedResult {
    let scalar_rows = RuntimeOptions {
        kernel: KernelKind::Scalar,
        packed_weights: false,
        ..Default::default()
    };
    let scalar_packed = RuntimeOptions { kernel: KernelKind::Scalar, ..Default::default() };
    let (row_major, packed) = bench_kernel_ab(
        dir,
        ("ref_kernel/row_major_scalar_b8", "ref_kernel/packed_scalar_b8"),
        scalar_rows,
        scalar_packed,
    );
    println!(
        "packed panels speedup (b8, scalar, per sample): {:.2}x \
         (row-major {row_major:.0} ns -> packed {packed:.0} ns)",
        row_major / packed.max(1e-9)
    );
    PackedResult { row_major_ns_per_sample: row_major, packed_ns_per_sample: packed }
}

/// Portable scalar vs runtime-dispatched explicit-SIMD microkernel,
/// packed layout both sides.
struct SimdResult {
    scalar_ns_per_sample: f64,
    simd_ns_per_sample: f64,
}

fn bench_simd_kernel(dir: &str) -> SimdResult {
    let scalar = RuntimeOptions { kernel: KernelKind::Scalar, ..Default::default() };
    // Auto: resolves to the AVX2+FMA microkernel where available —
    // exactly what a production load does.
    let auto = RuntimeOptions::default();
    let (scalar_ns, simd_ns) = bench_kernel_ab(
        dir,
        ("ref_kernel/scalar_packed_b8", "ref_kernel/simd_packed_b8"),
        scalar,
        auto,
    );
    let speedup = scalar_ns / simd_ns.max(1e-9);
    if simd_kernel_available() {
        if speedup >= 1.3 {
            println!("PASS: explicit-SIMD kernel {speedup:.2}x over scalar (>= 1.3x)");
        } else {
            println!("WARN: explicit-SIMD kernel speedup {speedup:.2}x < 1.3x");
        }
    } else {
        println!(
            "WARN: no AVX2+FMA on this host — simd_kernel measures scalar vs scalar \
             ({speedup:.2}x); the CI gate expects an AVX2 runner"
        );
    }
    SimdResult { scalar_ns_per_sample: scalar_ns, simd_ns_per_sample: simd_ns }
}

/// f32 vs i8 serving precision, packed panels + auto kernel both
/// sides, over the dense heavy-weight b8 variant and the recurrent
/// `edge_lstm` entry. Both legs are parameter-streaming bound, so the
/// 4x weight-byte shrink (tracked as bytes per MAC) is what the
/// speedup measures.
struct QuantizedResult {
    dense_f32_ns_per_sample: f64,
    dense_i8_ns_per_sample: f64,
    recurrent_f32_ns_per_sample: f64,
    recurrent_i8_ns_per_sample: f64,
    /// Weight bytes streamed per dense MAC at batch 8, per precision.
    f32_bytes_per_mac: f64,
    i8_bytes_per_mac: f64,
}

impl QuantizedResult {
    fn speedup(&self) -> f64 {
        self.dense_f32_ns_per_sample / self.dense_i8_ns_per_sample.max(1e-9)
    }
    fn recurrent_speedup(&self) -> f64 {
        self.recurrent_f32_ns_per_sample / self.recurrent_i8_ns_per_sample.max(1e-9)
    }
}

fn bench_quantized_gemm(dir: &str) -> QuantizedResult {
    let f32_rt = Runtime::load(dir).expect("bench runtime");
    let i8_rt = Runtime::load_with(
        dir,
        RuntimeOptions { precision: Precision::I8, ..Default::default() },
    )
    .expect("bench runtime");
    let dense_in = 8 * BENCH_IN;
    let lstm_in = QLSTM_T * 8 * QLSTM_D;
    let dense_f32 =
        bench_model_ns_per_sample(&f32_rt, "fam000_b8", dense_in, "ref_kernel/quant_dense_f32_b8");
    let dense_i8 =
        bench_model_ns_per_sample(&i8_rt, "fam000_b8", dense_in, "ref_kernel/quant_dense_i8_b8");
    let rec_f32 = bench_model_ns_per_sample(
        &f32_rt,
        "edge_lstm_b8",
        lstm_in,
        "ref_kernel/quant_lstm_f32_b8",
    );
    let rec_i8 =
        bench_model_ns_per_sample(&i8_rt, "edge_lstm_b8", lstm_in, "ref_kernel/quant_lstm_i8_b8");
    // Bytes per MAC: one full weight-streaming pass amortized over a
    // batch-8 chunk's dense MACs (the paper's arithmetic-intensity
    // axis, shifted by the i8 pack).
    let dense_macs = (8 * BENCH_IN * BENCH_OUT) as f64;
    let result = QuantizedResult {
        dense_f32_ns_per_sample: dense_f32,
        dense_i8_ns_per_sample: dense_i8,
        recurrent_f32_ns_per_sample: rec_f32,
        recurrent_i8_ns_per_sample: rec_i8,
        f32_bytes_per_mac: f32_rt.weight_bytes("fam000") as f64 / dense_macs,
        i8_bytes_per_mac: i8_rt.weight_bytes("fam000") as f64 / dense_macs,
    };
    println!(
        "quantized i8 speedup (b8, per sample): dense {:.2}x, recurrent {:.2}x \
         ({:.3} -> {:.3} weight bytes/MAC)",
        result.speedup(),
        result.recurrent_speedup(),
        result.f32_bytes_per_mac,
        result.i8_bytes_per_mac
    );
    if result.speedup() >= 1.0 {
        println!("PASS: i8 serving beats f32 on the dense leg (>= 1.0x)");
    } else {
        println!("WARN: i8 dense speedup {:.2}x < 1.0x", result.speedup());
    }
    result
}

/// One A/B serving comparison.
struct CaseResult {
    name: &'static str,
    /// Baseline / treatment labels for the JSON keys.
    labels: (&'static str, &'static str),
    baseline_rps: f64,
    treatment_rps: f64,
    /// Mean executed batch of the treatment run (the gemm case's
    /// "batch >= 4" witness).
    treatment_mean_batch: f64,
}

impl CaseResult {
    fn speedup(&self) -> f64 {
        self.treatment_rps / self.baseline_rps.max(1e-9)
    }
}

/// The overload A/B's headline numbers (the `overload_goodput` case).
struct OverloadResult {
    /// In-budget fraction of the full offered load, per arm.
    block_slo: f64,
    shed_slo: f64,
    /// `shed_slo / block_slo` — how much overload protection lifts
    /// SLO attainment at the same offered load.
    slo_gain: f64,
    /// In-budget responses per second of wall clock, per arm.
    block_goodput_rps: f64,
    shed_goodput_rps: f64,
}

/// The hierarchical-inference A/B (the `hier_escalation` case).
struct EscalationResult {
    always_large_rps: f64,
    hierarchical_rps: f64,
    /// Mean executed batch of the hierarchical arm.
    mean_batch: f64,
    /// Server-side `escalations / requests` of the hierarchical arm.
    escalated_frac: f64,
}

impl EscalationResult {
    fn speedup(&self) -> f64 {
        self.hierarchical_rps / self.always_large_rps.max(1e-9)
    }
}

/// The fault-tolerance A/B/C (the `degraded_failover` case).
struct FailoverResult {
    /// OK responses per second with the roster healthy.
    healthy_rps: f64,
    /// ... with the placed class blacked out, retry + breaker armed.
    failover_rps: f64,
    /// ... under the same blackout with recovery disabled
    /// (`retry_max = 0`, `breaker_threshold = 0`).
    no_failover_rps: f64,
}

impl FailoverResult {
    /// Goodput fraction failover retains under the blackout.
    fn retention(&self) -> f64 {
        self.failover_rps / self.healthy_rps.max(1e-9)
    }

    fn no_failover_retention(&self) -> f64 {
        self.no_failover_rps / self.healthy_rps.max(1e-9)
    }

    /// Failover retention over bare retention. The bare arm loses
    /// every placed request (its retention is exactly 0 — blackout is
    /// absolute and spill is parked out of reach), so the denominator
    /// is floored at 4%: the reported gain saturates at ~25x instead
    /// of diverging, keeping the CI regression band meaningful.
    fn retention_gain(&self) -> f64 {
        self.retention() / self.no_failover_retention().max(0.04)
    }
}

struct ServingResult {
    cases: Vec<CaseResult>,
    overload: OverloadResult,
    escalation: EscalationResult,
    failover: FailoverResult,
}

/// Family names that all hash to worker 0 of a `BENCH_WORKERS` pool —
/// the deterministic static-routing worst case (always constructible:
/// with more families than workers, some worker hosts several; we pin
/// the set so the measurement is reproducible).
fn colliding_families() -> Vec<String> {
    let mut fams = Vec::new();
    let mut i = 0usize;
    while fams.len() < BENCH_FAMILIES {
        let name = format!("fam{i:03}");
        if worker_for_family(&name, BENCH_WORKERS) == 0 {
            fams.push(name);
        }
        i += 1;
    }
    fams
}

/// Write the synthetic benchmark artifact manifest (dense families,
/// variants b1/b4/b8, reference backend — no HLO files needed).
fn write_bench_artifacts(families: &[String]) -> String {
    // Per-process dir: concurrent runs (or different users on one
    // machine) must not race on the manifest.
    let dir =
        std::env::temp_dir().join(format!("mensa_bench_artifacts_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create bench artifacts dir");
    let mut manifest = String::from("# Generated by hotpath_micro — synthetic serving families.\n");
    for family in families {
        for b in [1usize, 4, 8] {
            let _ = write!(
                manifest,
                "\n[[artifact]]\nname = \"{family}_b{b}\"\nfile = \"{family}_b{b}.hlo.txt\"\n\
                 num_inputs = 1\ninput0_shape = \"{b}x{BENCH_IN}\"\ninput0_batch_axis = 0\n\
                 output_shape = \"{b}x{BENCH_OUT}\"\noutput_batch_axis = 0\n\
                 sha256 = \"referencebackend\"\n"
            );
        }
    }
    // Hierarchical-escalation pair: same input geometry, 16x apart in
    // output width (≈ MAC cost), so "small first, escalate only the
    // low-confidence tail" has real compute to save.
    for (family, out) in [("esc_small", ESC_SMALL_OUT), ("esc_large", ESC_LARGE_OUT)] {
        for b in [1usize, 4, 8] {
            let _ = write!(
                manifest,
                "\n[[artifact]]\nname = \"{family}_b{b}\"\nfile = \"{family}_b{b}.hlo.txt\"\n\
                 num_inputs = 1\ninput0_shape = \"{b}x{BENCH_IN}\"\ninput0_batch_axis = 0\n\
                 output_shape = \"{b}x{out}\"\noutput_batch_axis = 0\n\
                 sha256 = \"referencebackend\"\n"
            );
        }
    }
    // Quantized-A/B recurrent leg: a time-major `edge_lstm` entry
    // (the reference backend's recurrent path keys on the family
    // name) with square QLSTM_D-wide gate matrices.
    let _ = write!(
        manifest,
        "\n[[artifact]]\nname = \"edge_lstm_b8\"\nfile = \"edge_lstm_b8.hlo.txt\"\n\
         num_inputs = 1\ninput0_shape = \"{QLSTM_T}x8x{QLSTM_D}\"\ninput0_batch_axis = 1\n\
         output_shape = \"{QLSTM_T}x8x{QLSTM_D}\"\noutput_batch_axis = 1\n\
         sha256 = \"referencebackend\"\n"
    );
    // Layer-pipeline family: `edge_rcnn` proxies to the zoo's mixed
    // CNN-front/LSTM-back RCNN1 for profiling, and its PIPE_STAGES
    // dense input blocks give the reference backend that many runtime
    // stages for `segment_level` to cut.
    for b in [1usize, 4, 8] {
        let _ = write!(
            manifest,
            "\n[[artifact]]\nname = \"edge_rcnn_b{b}\"\nfile = \"edge_rcnn_b{b}.hlo.txt\"\n\
             num_inputs = {PIPE_STAGES}\n"
        );
        for i in 0..PIPE_STAGES {
            let _ =
                write!(manifest, "input{i}_shape = \"{b}x{BENCH_IN}\"\ninput{i}_batch_axis = 0\n");
        }
        let _ = write!(
            manifest,
            "output_shape = \"{b}x{BENCH_OUT}\"\noutput_batch_axis = 0\n\
             sha256 = \"referencebackend\"\n"
        );
    }
    std::fs::write(dir.join("manifest.toml"), manifest).expect("write bench manifest");
    dir.to_str().expect("utf8 temp dir").to_string()
}

/// Deterministic 20-slot request pattern: index 0 is the hot family
/// (6/20 = 30%), the rest spread evenly.
const SKEW_PATTERN: [usize; 20] = [0, 1, 2, 0, 3, 4, 0, 5, 6, 0, 7, 1, 0, 2, 3, 0, 4, 5, 6, 7];

/// How one serving run routes, executes, and orders.
#[derive(Clone, Copy)]
struct CaseOpts {
    stealing: bool,
    /// `skewed`: SKEW_PATTERN; `!skewed`: uniform round-robin — unless
    /// `single_family` / `shifting` override the choice.
    skewed: bool,
    single_family: bool,
    /// Shifting 100% skew: the hot family alternates between
    /// families[0] and families[1] each quarter of the run (the
    /// adaptive-depth case's load).
    shifting: bool,
    device_us: u64,
    batched_gemm: bool,
    reorder_depth: usize,
    /// Adaptive per-family depth clamp (0 = static `reorder_depth`).
    reorder_depth_max: usize,
    /// Chunk-granular sequencing (batcher pre-splits oversized
    /// flushes); `false` is the job-granular baseline.
    chunk_level: bool,
    max_batch: usize,
    /// Closed-loop burst size (wait for each burst's responses before
    /// submitting the next); 0 = open loop. Bursts keep exactly one
    /// oversized job in flight — the chunk-granularity A/B.
    burst: usize,
}

struct RunStats {
    rps: f64,
    mean_batch: f64,
}

/// Which family request `k` of a run targets.
fn family_index(opts: CaseOpts, k: usize, n_families: usize) -> usize {
    if opts.single_family {
        0
    } else if opts.shifting {
        (k / (BENCH_REQUESTS / 4).max(1)) % 2
    } else if opts.skewed {
        SKEW_PATTERN[k % SKEW_PATTERN.len()]
    } else {
        k % n_families
    }
}

/// Submit one request, retrying backpressure rejections but failing
/// fast (instead of hanging CI) if the server has actually died.
fn submit_with_retry(
    server: &mensa::coordinator::ServerHandle,
    family: &str,
    input: &[f32],
) -> std::sync::mpsc::Receiver<anyhow::Result<mensa::coordinator::InferenceResponse>> {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        match server.infer_request(family, vec![input.to_vec()]).send() {
            Ok(rx) => return rx,
            Err(e) => {
                assert!(
                    Instant::now() < deadline,
                    "bench submission stalled for 120s (server dead?): {e:#}"
                );
                std::thread::sleep(Duration::from_micros(200));
            }
        }
    }
}

/// Run one serving case; returns completed requests/second and the
/// mean executed batch.
fn run_case(dir: &str, families: &[String], opts: CaseOpts) -> RunStats {
    run_case_with(dir, families, opts, Vec::new())
}

/// [`run_case`] with an explicit `[[device]]` roster (empty = the
/// homogeneous pre-roster pool). A multi-class roster additionally
/// asserts that at least two device classes actually executed jobs
/// (`jobs_by_device`) — the heterogeneous pool's liveness witness.
fn run_case_with(
    dir: &str,
    families: &[String],
    opts: CaseOpts,
    devices: Vec<DeviceClassSpec>,
) -> RunStats {
    let multi_class = devices.len() > 1;
    let cfg = ServerConfig {
        workers: BENCH_WORKERS,
        max_batch: opts.max_batch,
        // Burst mode accumulates a whole burst into one flush; give
        // the batcher enough slack to see the burst's final request.
        batch_timeout_us: if opts.burst > 0 { 3_000 } else { 300 },
        queue_depth: 2 * BENCH_REQUESTS,
        work_stealing: opts.stealing,
        // One shard in ALL modes: the comparisons isolate routing /
        // kernels / ordering (sharding is a separate axis, and the
        // colliding family set would all land on shard 0 anyway).
        batcher_shards: 1,
        naive_kernels: false,
        kernel: KernelKind::Auto,
        packed_weights: true,
        device_latency_us: opts.device_us,
        batched_gemm: opts.batched_gemm,
        reorder_depth: opts.reorder_depth,
        reorder_depth_max: opts.reorder_depth_max,
        chunk_level: opts.chunk_level,
        segment_level: false,
        max_segments: PIPE_STAGES,
        panic_on_poison: false,
        devices,
        transfer_us: 50,
        // Large vs the emulated windows: placement holds while the
        // preferred class keeps up, spill only rescues a stall.
        spill_after_us: 20_000,
        // The classic cases serve without deadlines, tiers, or fault
        // tolerance; the overload / escalation / failover cases build
        // their own configs.
        deadline_us: 0,
        overload: OverloadPolicy::Block,
        families: Vec::new(),
        escalation_threshold: 0.35,
        retry_max: 0,
        breaker_threshold: 0,
        breaker_cooldown_us: 250_000,
        fault: None,
    };
    let server = Server::start(dir, cfg).expect("bench server start");
    let input: Vec<f32> = (0..BENCH_IN).map(|i| ((i % 23) as f32 - 11.0) / 23.0).collect();
    let t0 = Instant::now();
    if opts.burst > 0 {
        // Closed loop: one burst (one oversized flush) in flight at a
        // time.
        let mut k = 0;
        while k < BENCH_REQUESTS {
            let n = opts.burst.min(BENCH_REQUESTS - k);
            let mut rxs = Vec::with_capacity(n);
            for i in 0..n {
                let family = &families[family_index(opts, k + i, families.len())];
                rxs.push(submit_with_retry(&server, family, &input));
            }
            for rx in rxs {
                rx.recv_timeout(Duration::from_secs(120))
                    .expect("bench recv")
                    .expect("bench ok");
            }
            k += n;
        }
    } else {
        let mut rxs = Vec::with_capacity(BENCH_REQUESTS);
        for k in 0..BENCH_REQUESTS {
            let family = &families[family_index(opts, k, families.len())];
            rxs.push(submit_with_retry(&server, family, &input));
        }
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(120)).expect("bench recv").expect("bench ok");
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = server.metrics();
    assert_eq!(snap.fifo_violations, 0, "bench load must stay FIFO (reorder contract)");
    if multi_class {
        assert!(
            snap.jobs_by_device.len() >= 2,
            "heterogeneous roster must execute on >= 2 device classes, got {:?}",
            snap.jobs_by_device
        );
    }
    server.shutdown();
    RunStats { rps: BENCH_REQUESTS as f64 / wall, mean_batch: snap.mean_batch }
}

/// One `latency_scale` shared by BOTH `mensa_placement` arms:
/// calibrated so the slowest (class, family) modeled base latency
/// across every candidate class lands at `BENCH_DEVICE_US`. Sharing
/// the scale keeps the arms comparable — the A/B measures *placement*,
/// not a rescaling artifact.
fn mensa_roster_scale(families: &[String]) -> f64 {
    let candidates = [
        DeviceClass::Baseline,
        DeviceClass::Pascal,
        DeviceClass::Pavlov,
        DeviceClass::Jacquard,
    ];
    let specs: Vec<DeviceClassSpec> = candidates
        .iter()
        .map(|&class| DeviceClassSpec { class, workers: 1, latency_scale: 1.0 })
        .collect();
    let profiles = device::build_profiles(&specs, families, Duration::ZERO);
    let mut max_base = 0.0f64;
    for p in &profiles {
        for f in families {
            max_base = max_base.max(p.base_latency_s(f));
        }
    }
    (BENCH_DEVICE_US as f64 * 1e-6) / max_base.max(1e-12)
}

/// One arm of the overload A/B: in-budget fraction of the offered
/// load and in-budget responses per second.
struct OverloadArm {
    slo: f64,
    goodput_rps: f64,
}

/// Burst sizes for the overload arms, drawn from the repo PRNG with a
/// pinned seed so BOTH arms offer the identical arrival sequence:
/// ~60 requests every 4 ms against a 4 req/ms service capacity (~4x).
fn overload_bursts() -> Vec<usize> {
    let mut rng = Rng::new(0x0BAD_10AD);
    let mut bursts = Vec::new();
    let mut left = OVERLOAD_REQUESTS;
    while left > 0 {
        let n = rng.range_usize(40, 80).min(left);
        bursts.push(n);
        left -= n;
    }
    bursts
}

/// Run one overload arm. Every request carries the config-default
/// deadline; `shed` selects the overload policy. Admission rejections,
/// enqueue sheds, dequeue expiries, and late responses all count
/// against SLO attainment — the numerator is "answered within budget",
/// the denominator the full offered load, so the arms compare fairly
/// even though the shed arm answers far fewer requests.
fn run_overload_arm(dir: &str, family: &str, shed: bool) -> OverloadArm {
    let overload = if shed {
        OverloadPolicy::Shed
    } else {
        OverloadPolicy::Block
    };
    let cfg = ServerConfig {
        workers: BENCH_WORKERS,
        max_batch: 1,
        batch_timeout_us: 200,
        queue_depth: 2 * OVERLOAD_REQUESTS,
        work_stealing: true,
        batcher_shards: 1,
        naive_kernels: false,
        kernel: KernelKind::Auto,
        packed_weights: true,
        device_latency_us: BENCH_DEVICE_US,
        batched_gemm: true,
        reorder_depth: BENCH_WORKERS,
        reorder_depth_max: 0,
        chunk_level: true,
        segment_level: false,
        max_segments: PIPE_STAGES,
        panic_on_poison: false,
        devices: Vec::new(),
        transfer_us: 50,
        spill_after_us: 20_000,
        deadline_us: OVERLOAD_DEADLINE_US,
        overload,
        families: Vec::new(),
        escalation_threshold: 0.35,
        retry_max: 0,
        breaker_threshold: 0,
        breaker_cooldown_us: 250_000,
        fault: None,
    };
    let server = Server::start(dir, cfg).expect("bench server start");
    let budget = Duration::from_micros(OVERLOAD_DEADLINE_US);
    let input: Vec<f32> = (0..BENCH_IN).map(|i| ((i % 23) as f32 - 11.0) / 23.0).collect();
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    let mut rejected = 0usize;
    for burst in overload_bursts() {
        for _ in 0..burst {
            // Admission control rejects some submissions outright in
            // the shed arm; those count against SLO attainment, not as
            // bench failures.
            match server.infer_request(family, vec![input.clone()]).send() {
                Ok(rx) => rxs.push(rx),
                Err(_) => rejected += 1,
            }
        }
        std::thread::sleep(Duration::from_millis(4));
    }
    let mut served = 0usize;
    let mut in_time = 0usize;
    for rx in rxs {
        // Enqueue sheds / dequeue expiries reply with an error — they
        // simply never make the in-budget numerator.
        if let Ok(resp) = rx.recv_timeout(Duration::from_secs(120)).expect("bench recv") {
            served += 1;
            if resp.latency <= budget {
                in_time += 1;
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = server.metrics();
    assert_eq!(snap.fifo_violations, 0, "bench load must stay FIFO (reorder contract)");
    assert_eq!(snap.failed, 0, "overload outcomes must be sheds/expiries, not failures");
    // Conservation: every offered request is answered or shed exactly
    // once, whether it was refused at admission, at enqueue, or at
    // dequeue.
    assert_eq!(
        snap.completed + snap.jobs_shed + snap.jobs_expired,
        OVERLOAD_REQUESTS as u64,
        "offered = completed + shed + expired (admission rejections: {rejected})"
    );
    if !shed {
        assert_eq!(served, OVERLOAD_REQUESTS, "block arm must answer the full offered load");
    }
    server.shutdown();
    OverloadArm {
        slo: in_time as f64 / OVERLOAD_REQUESTS as f64,
        goodput_rps: in_time as f64 / wall,
    }
}

/// Calibrated two-class roster for the `degraded_failover` A/B/C,
/// plus the load family's placed (primary) class label. The shared
/// `latency_scale` pins the SLOWEST class's batch-1 window for the
/// family at `FAILOVER_DEVICE_US`, so the backup class can always
/// absorb the paced offered load on its own.
fn failover_roster(family: &str) -> (Vec<DeviceClassSpec>, String) {
    let probe = vec![
        DeviceClassSpec { class: DeviceClass::Pascal, workers: 1, latency_scale: 1.0 },
        DeviceClassSpec { class: DeviceClass::Pavlov, workers: 1, latency_scale: 1.0 },
    ];
    let fams = vec![family.to_string()];
    let profiles = device::build_profiles(&probe, &fams, Duration::ZERO);
    let slowest = profiles.iter().map(|p| p.base_latency_s(family)).fold(0.0f64, f64::max);
    let scale = (FAILOVER_DEVICE_US as f64 * 1e-6) / slowest.max(1e-12);
    let specs: Vec<DeviceClassSpec> =
        probe.into_iter().map(|s| DeviceClassSpec { latency_scale: scale, ..s }).collect();
    let profiles = device::build_profiles(&specs, &fams, Duration::ZERO);
    let ranking = device::placement_ranking(&profiles, &fams);
    let primary = profiles[ranking[family][0]].class().to_string();
    (specs, primary)
}

/// Run one `degraded_failover` arm: `FAILOVER_REQUESTS` single-family
/// requests paced in bursts at ~70% of the backup class's service
/// capacity, so every arm's wall clock is arrival-dominated and the
/// goodput ratios reduce to completed fractions (stable across
/// hosts). Returns OK responses per second of wall clock.
fn run_failover_arm(
    dir: &str,
    family: &str,
    devices: Vec<DeviceClassSpec>,
    fault: Option<FaultPlan>,
    failover: bool,
) -> f64 {
    let degraded = fault.is_some();
    let cfg = ServerConfig {
        workers: 2,
        max_batch: 1,
        batch_timeout_us: 200,
        queue_depth: 2 * FAILOVER_REQUESTS,
        work_stealing: true,
        batcher_shards: 1,
        naive_kernels: false,
        kernel: KernelKind::Auto,
        packed_weights: true,
        device_latency_us: 0,
        batched_gemm: true,
        reorder_depth: 0,
        reorder_depth_max: 0,
        chunk_level: true,
        segment_level: false,
        max_segments: PIPE_STAGES,
        panic_on_poison: false,
        devices,
        transfer_us: 50,
        // Parked far out of reach: spill stealing must never quietly
        // rescue (or re-poison) a placement across classes mid-arm —
        // recovery has to come from the breaker re-placement, or not
        // at all.
        spill_after_us: 10_000_000,
        deadline_us: 0,
        overload: OverloadPolicy::Block,
        families: Vec::new(),
        escalation_threshold: 0.35,
        retry_max: if failover { 10 } else { 0 },
        breaker_threshold: if failover { 2 } else { 0 },
        // One trip decides the arm: no half-open probe mid-run.
        breaker_cooldown_us: 3_600_000_000,
        fault,
    };
    let server = Server::start(dir, cfg).expect("bench server start");
    let input: Vec<f32> = (0..BENCH_IN).map(|i| ((i % 23) as f32 - 11.0) / 23.0).collect();
    let t0 = Instant::now();
    let mut rxs = Vec::with_capacity(FAILOVER_REQUESTS);
    let mut k = 0;
    while k < FAILOVER_REQUESTS {
        let n = FAILOVER_BURST.min(FAILOVER_REQUESTS - k);
        for _ in 0..n {
            rxs.push(submit_with_retry(&server, family, &input));
        }
        k += n;
        std::thread::sleep(Duration::from_micros(FAILOVER_BURST as u64 * 1_000));
    }
    let mut ok = 0usize;
    for rx in rxs {
        if rx.recv_timeout(Duration::from_secs(120)).expect("bench recv").is_ok() {
            ok += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = server.metrics();
    assert_eq!(snap.fifo_violations, 0, "bench load must stay FIFO (reorder contract)");
    assert_eq!(
        snap.completed + snap.failed,
        FAILOVER_REQUESTS as u64,
        "every offered request must terminate as completed or failed"
    );
    if !degraded {
        assert_eq!(snap.failed, 0, "the healthy arm must not fail requests");
        assert_eq!(snap.breaker_trips, 0, "the healthy arm must not trip the breaker");
    } else if failover {
        assert_eq!(snap.failed, 0, "failover must recover every blacked-out request");
        assert!(snap.breaker_trips >= 1, "the blacked-out class must trip its breaker");
        assert!(snap.failovers >= 1, "the placed family must fail over");
        assert!(snap.jobs_retried >= 1, "recovery must ride the retry path");
    } else {
        assert!(snap.failed > 0, "no-failover under blackout must lose requests");
    }
    server.shutdown();
    ok as f64 / wall
}

/// Client-side mirror of the server's confidence score (peak share of
/// the output's absolute mass), used to probe the small variant's
/// confidence distribution before the hierarchical arm runs.
fn output_confidence(xs: &[f32]) -> f64 {
    let mut peak = 0.0f64;
    let mut mass = 0.0f64;
    for &x in xs {
        let a = f64::from(x.abs());
        peak = peak.max(a);
        mass += a;
    }
    if mass > 0.0 { peak / mass } else { 0.0 }
}

/// The escalation A/B's request set: per-request pseudo-random inputs
/// (pinned seed) so the small variant's confidences form a spread the
/// median threshold can split.
fn escalation_inputs() -> Vec<Vec<f32>> {
    let mut rng = Rng::new(0xE5CA_1A7E);
    (0..ESC_REQUESTS)
        .map(|_| (0..BENCH_IN).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect())
        .collect()
}

/// Probe the small variant's confidences on the exact bench inputs
/// (batched serving is bit-identical to batch-1, so the bare-runtime
/// confidences equal the served ones) and pin the escalation threshold
/// between the two central order statistics — with distinct
/// confidences exactly half the requests escalate.
fn probe_escalation_threshold(dir: &str, inputs: &[Vec<f32>]) -> f64 {
    let rt = Runtime::load(dir).expect("bench runtime");
    let model = rt.model("esc_small_b1").expect("esc_small_b1");
    let mut confs: Vec<f64> = inputs
        .iter()
        .map(|input| {
            let out = model.execute(&[input.clone()]).expect("probe exec");
            output_confidence(&out)
        })
        .collect();
    confs.sort_by(|a, b| a.partial_cmp(b).expect("finite confidence"));
    let (lo, hi) = (confs[0], confs[confs.len() - 1]);
    let mut t = 0.5 * (confs[confs.len() / 2 - 1] + confs[confs.len() / 2]);
    if t <= lo || t > hi {
        // Tie-degenerate lower half: any threshold strictly inside
        // (lo, hi] keeps the escalated fraction in (0, 1).
        t = 0.5 * (lo + hi);
    }
    if lo >= hi {
        // All-equal distribution: escalate everything rather than
        // nothing, so the path is still exercised (and the speedup
        // honestly reports the escalation overhead).
        t = hi + hi.abs() * 1e-9 + f64::EPSILON;
    }
    t.min(1.0)
}

/// Server config shared by both escalation arms; `hierarchical` adds
/// the `[[family]]` entry that routes low-confidence small-variant
/// outputs to the large variant.
fn escalation_config(threshold: f64, hierarchical: bool) -> ServerConfig {
    ServerConfig {
        workers: BENCH_WORKERS,
        max_batch: 8,
        batch_timeout_us: 300,
        queue_depth: 2 * ESC_REQUESTS,
        work_stealing: true,
        batcher_shards: 1,
        naive_kernels: false,
        kernel: KernelKind::Auto,
        packed_weights: true,
        device_latency_us: 0,
        batched_gemm: true,
        // Full pool concurrency for BOTH arms, so the A/B measures the
        // compute saved by the small-first pass, not a family-lease
        // serialization artifact.
        reorder_depth: BENCH_WORKERS,
        reorder_depth_max: 0,
        chunk_level: true,
        segment_level: false,
        max_segments: PIPE_STAGES,
        panic_on_poison: false,
        devices: Vec::new(),
        transfer_us: 50,
        spill_after_us: 20_000,
        deadline_us: 0,
        overload: OverloadPolicy::Block,
        families: if hierarchical {
            vec![FamilyPolicy {
                name: "esc_small".to_string(),
                priority: 0,
                escalate_to: Some("esc_large".to_string()),
                precision: Precision::F32,
            }]
        } else {
            Vec::new()
        },
        escalation_threshold: threshold,
        retry_max: 0,
        breaker_threshold: 0,
        breaker_cooldown_us: 250_000,
        fault: None,
    }
}

/// Run one escalation arm open-loop over `inputs`; returns (rps, mean
/// executed batch, escalated fraction). Large-shaped responses must
/// match the server's escalation counter one-for-one.
fn run_escalation_arm(
    dir: &str,
    family: &str,
    cfg: ServerConfig,
    inputs: &[Vec<f32>],
) -> (f64, f64, f64) {
    let server = Server::start(dir, cfg).expect("bench server start");
    let t0 = Instant::now();
    let mut rxs = Vec::with_capacity(inputs.len());
    for input in inputs {
        rxs.push(submit_with_retry(&server, family, input));
    }
    let mut large_outputs = 0usize;
    for rx in rxs {
        let resp =
            rx.recv_timeout(Duration::from_secs(120)).expect("bench recv").expect("bench ok");
        if resp.output.len() == ESC_LARGE_OUT {
            large_outputs += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = server.metrics();
    assert_eq!(snap.fifo_violations, 0, "bench load must stay FIFO (reorder contract)");
    if family == "esc_small" {
        assert_eq!(
            snap.escalations as usize,
            large_outputs,
            "every large-shaped response is exactly one escalation"
        );
    } else {
        assert_eq!(snap.escalations, 0, "the always-large arm must not escalate");
    }
    server.shutdown();
    (inputs.len() as f64 / wall, snap.mean_batch, snap.escalations as f64 / inputs.len() as f64)
}

/// Deterministic per-request input sets for the `layer_pipeline`
/// arms: every arm serves the identical load, so responses compare
/// bit-for-bit across monolithic, segmented, and cross-class runs.
fn pipeline_inputs() -> Vec<Vec<Vec<f32>>> {
    let mut rng = Rng::new(0x9199_11E5);
    (0..PIPE_REQUESTS)
        .map(|_| {
            (0..PIPE_STAGES)
                .map(|_| (0..BENCH_IN).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect())
                .collect()
        })
        .collect()
}

/// Calibrated two-class roster for the pipeline's heterogeneous leg:
/// the shared `latency_scale` pins the slowest class's batch-1 window
/// for `edge_rcnn` at `BENCH_DEVICE_US` (the [`failover_roster`]
/// recipe), and the 2 + 2 worker split keeps the pool at
/// `BENCH_WORKERS` so the legs stay comparable.
fn pipeline_roster() -> Vec<DeviceClassSpec> {
    let probe = vec![
        DeviceClassSpec { class: DeviceClass::Pascal, workers: 2, latency_scale: 1.0 },
        DeviceClassSpec { class: DeviceClass::Pavlov, workers: 2, latency_scale: 1.0 },
    ];
    let fams = vec!["edge_rcnn".to_string()];
    let profiles = device::build_profiles(&probe, &fams, Duration::ZERO);
    let slowest =
        profiles.iter().map(|p| p.base_latency_s("edge_rcnn")).fold(0.0f64, f64::max);
    let scale = (BENCH_DEVICE_US as f64 * 1e-6) / slowest.max(1e-12);
    probe.into_iter().map(|s| DeviceClassSpec { latency_scale: scale, ..s }).collect()
}

/// Run one `layer_pipeline` arm: `PIPE_REQUESTS` open-loop requests on
/// the multi-stage `edge_rcnn` family under the family-lease
/// discipline (`reorder_depth = 0`). The lease is the point of the
/// A/B: the monolithic arm's chunks serialize on one worker at a
/// time, while the segmented arm's continuation lanes (`edge_rcnn@s`)
/// each hold their own lease, so the same strictly-FIFO stream fills
/// one worker per pipeline stage. Returns the run's stats, every
/// response output in submission order (the bit-exactness witness),
/// and the charged cross-class transfer count.
fn run_pipeline_arm(
    dir: &str,
    segmented: bool,
    devices: Vec<DeviceClassSpec>,
    inputs: &[Vec<Vec<f32>>],
) -> (RunStats, Vec<Vec<f32>>, u64) {
    let multi_class = devices.len() > 1;
    let cfg = ServerConfig {
        workers: BENCH_WORKERS,
        max_batch: 8,
        batch_timeout_us: 300,
        queue_depth: 2 * PIPE_REQUESTS,
        work_stealing: true,
        batcher_shards: 1,
        naive_kernels: false,
        kernel: KernelKind::Auto,
        packed_weights: true,
        // Roster legs take their windows from the calibrated class
        // profiles, flat legs from the legacy knob (as mensa_placement
        // does).
        device_latency_us: if multi_class { 0 } else { BENCH_DEVICE_US },
        batched_gemm: true,
        reorder_depth: 0,
        reorder_depth_max: 0,
        chunk_level: true,
        segment_level: segmented,
        max_segments: PIPE_STAGES,
        panic_on_poison: false,
        devices,
        transfer_us: 50,
        spill_after_us: 20_000,
        deadline_us: 0,
        overload: OverloadPolicy::Block,
        families: Vec::new(),
        escalation_threshold: 0.35,
        retry_max: 0,
        breaker_threshold: 0,
        breaker_cooldown_us: 250_000,
        fault: None,
    };
    let server = Server::start(dir, cfg).expect("bench server start");
    let t0 = Instant::now();
    let mut rxs = Vec::with_capacity(inputs.len());
    for req in inputs {
        let deadline = Instant::now() + Duration::from_secs(120);
        loop {
            match server.infer_request("edge_rcnn", req.clone()).send() {
                Ok(rx) => break rxs.push(rx),
                Err(e) => {
                    assert!(Instant::now() < deadline, "pipeline submission stalled: {e:#}");
                    std::thread::sleep(Duration::from_micros(200));
                }
            }
        }
    }
    let mut outputs = Vec::with_capacity(inputs.len());
    for rx in rxs {
        let resp =
            rx.recv_timeout(Duration::from_secs(120)).expect("bench recv").expect("bench ok");
        outputs.push(resp.output);
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = server.metrics();
    assert_eq!(snap.fifo_violations, 0, "bench load must stay FIFO (reorder contract)");
    assert_eq!(snap.failed, 0, "pipeline arms must not fail requests");
    if segmented {
        assert!(
            snap.segments_executed >= 2 * snap.jobs,
            "segmented arm must cut every chunk ({} segments over {} jobs)",
            snap.segments_executed,
            snap.jobs
        );
        assert_eq!(
            snap.segment_hops,
            snap.segments_executed - snap.jobs,
            "every non-final segment hands off exactly once"
        );
        let workers = snap
            .workers_by_family
            .iter()
            .find(|(f, _)| f == "edge_rcnn")
            .map(|(_, ws)| ws.len())
            .unwrap_or(0);
        assert!(workers >= 2, "single hot stream must pipeline across >= 2 workers");
    } else {
        assert_eq!(snap.segments_executed, 0, "monolithic arm must not segment");
    }
    if multi_class {
        assert!(
            snap.jobs_by_device.len() >= 2,
            "roster leg must execute on >= 2 device classes, got {:?}",
            snap.jobs_by_device
        );
        if segmented {
            assert!(
                snap.cross_device_transfers > 0,
                "cross-class pipeline must charge activation transfers"
            );
        }
    }
    server.shutdown();
    let stats = RunStats { rps: inputs.len() as f64 / wall, mean_batch: snap.mean_batch };
    (stats, outputs, snap.cross_device_transfers)
}

fn bench_serving(dir: &str, families: &[String]) -> ServingResult {
    timer::header("serving_throughput");
    println!(
        "synthetic families (all statically pinned to worker 0 of {BENCH_WORKERS}): {families:?}"
    );
    let defaults = CaseOpts {
        stealing: true,
        skewed: true,
        single_family: false,
        shifting: false,
        device_us: 0,
        batched_gemm: true,
        reorder_depth: 0,
        reorder_depth_max: 0,
        chunk_level: true,
        max_batch: 8,
        burst: 0,
    };
    let mut cases = Vec::new();

    // Routing comparisons (PR 2's cases): static vs stealing.
    for (name, skewed, device_us) in [
        ("skewed_device_emulated", true, BENCH_DEVICE_US),
        ("skewed_cpu_bound", true, 0),
        ("uniform_cpu_bound", false, 0),
    ] {
        let routed = CaseOpts { skewed, device_us, ..defaults };
        let base = run_case(dir, families, CaseOpts { stealing: false, ..routed });
        let treat = run_case(dir, families, routed);
        push_case(
            &mut cases,
            CaseResult {
                name,
                labels: ("static_rps", "stealing_rps"),
                baseline_rps: base.rps,
                treatment_rps: treat.rps,
                treatment_mean_batch: treat.mean_batch,
            },
        );
    }

    // Kernel comparison (PR 3 tentpole): per-sample vs batched GEMM,
    // stealing both sides, CPU-bound so kernel time dominates.
    let base = run_case(dir, families, CaseOpts { batched_gemm: false, ..defaults });
    let treat = run_case(dir, families, defaults);
    let gemm_batch = treat.mean_batch;
    push_case(
        &mut cases,
        CaseResult {
            name: "skewed_gemm",
            labels: ("per_sample_rps", "batched_rps"),
            baseline_rps: base.rps,
            treatment_rps: treat.rps,
            treatment_mean_batch: treat.mean_batch,
        },
    );

    // Ordering-discipline comparison (PR 3 tentpole): one hot family,
    // device emulation — the lease serializes its jobs on one worker
    // at a time; the reorder buffer fans them across the pool while
    // run_case asserts fifo_violations == 0.
    let hot = CaseOpts {
        skewed: false,
        single_family: true,
        device_us: BENCH_DEVICE_US,
        ..defaults
    };
    let base = run_case(dir, families, hot);
    let treat = run_case(dir, families, CaseOpts { reorder_depth: BENCH_WORKERS, ..hot });
    push_case(
        &mut cases,
        CaseResult {
            name: "hot_family_reorder",
            labels: ("lease_rps", "reorder_rps"),
            baseline_rps: base.rps,
            treatment_rps: treat.rps,
            treatment_mean_batch: treat.mean_batch,
        },
    );

    // Chunk-granularity comparison (PR 4 tentpole): closed-loop bursts
    // keep exactly ONE oversized job (32 requests = four b8 chunks) in
    // flight. Job-granular sequencing runs the four chunks
    // front-to-back on one worker — four serial device windows per
    // burst; chunk-granular spreads them across the pool, so the
    // device windows overlap regardless of host core count.
    let oversized = CaseOpts {
        skewed: false,
        single_family: true,
        device_us: 2 * BENCH_DEVICE_US,
        max_batch: 32,
        burst: 32,
        reorder_depth: BENCH_WORKERS,
        ..defaults
    };
    let base = run_case(dir, families, CaseOpts { chunk_level: false, ..oversized });
    let treat = run_case(dir, families, oversized);
    push_case(
        &mut cases,
        CaseResult {
            name: "oversized_job_chunks",
            labels: ("job_granular_rps", "chunk_granular_rps"),
            baseline_rps: base.rps,
            treatment_rps: treat.rps,
            treatment_mean_batch: treat.mean_batch,
        },
    );

    // Adaptive-depth comparison (PR 4 tentpole): shifting 100% skew —
    // the hot family alternates each quarter of the run. The static
    // lease serializes whichever family is hot; the adaptive policy
    // (`reorder_depth_max = workers`) widens it automatically as its
    // backlog builds and releases the width when the skew moves on.
    let shifting = CaseOpts {
        skewed: false,
        shifting: true,
        device_us: BENCH_DEVICE_US,
        ..defaults
    };
    let base = run_case(dir, families, shifting);
    let treat = run_case(dir, families, CaseOpts { reorder_depth_max: BENCH_WORKERS, ..shifting });
    push_case(
        &mut cases,
        CaseResult {
            name: "adaptive_depth",
            labels: ("static_rps", "adaptive_rps"),
            baseline_rps: base.rps,
            treatment_rps: treat.rps,
            treatment_mean_batch: treat.mean_batch,
        },
    );

    // Mensa-placement comparison (PR 6 tentpole): the zoo's skewed mix
    // on two equal-size `[[device]]` rosters — three homogeneous
    // Edge-TPU-baseline workers vs Pascal + Pavlov + Jacquard with
    // placement-aware dispatch. Same calibrated latency_scale on both
    // sides: the only difference is which class's emulated window each
    // family pays, i.e. the placement itself. The synthetic families
    // proxy-cycle over the zoo's CNN / LSTM / transducer models, so
    // the mix contains both bandwidth-starved and compute-bound work.
    let scale = mensa_roster_scale(families);
    let homogeneous = vec![DeviceClassSpec {
        class: DeviceClass::Baseline,
        workers: 3,
        latency_scale: scale,
    }];
    let mensa_pool = vec![
        DeviceClassSpec { class: DeviceClass::Pascal, workers: 1, latency_scale: scale },
        DeviceClassSpec { class: DeviceClass::Pavlov, workers: 1, latency_scale: scale },
        DeviceClassSpec { class: DeviceClass::Jacquard, workers: 1, latency_scale: scale },
    ];
    // Device windows come from the roster profiles; the legacy flat
    // knob stays off.
    let placed = CaseOpts { device_us: 0, ..defaults };
    let base = run_case_with(dir, families, placed, homogeneous);
    let treat = run_case_with(dir, families, placed, mensa_pool);
    push_case(
        &mut cases,
        CaseResult {
            name: "mensa_placement",
            labels: ("homogeneous_rps", "mensa_rps"),
            baseline_rps: base.rps,
            treatment_rps: treat.rps,
            treatment_mean_batch: treat.mean_batch,
        },
    );

    // Layer-pipeline comparison (PR 9 tentpole): a single hot
    // multi-stage stream under the family lease, monolithic vs
    // profiled per-layer segments pipelined across the pool. Both
    // arms serve the identical pinned load; responses must match
    // bit-for-bit (same kernels, same per-sample walk — the pipeline
    // only moves WHERE each stage range runs).
    let pipe_inputs = pipeline_inputs();
    let (mono, mono_out, _) = run_pipeline_arm(dir, false, Vec::new(), &pipe_inputs);
    let (seg, seg_out, _) = run_pipeline_arm(dir, true, Vec::new(), &pipe_inputs);
    assert_eq!(mono_out, seg_out, "segmented pipeline must stay bit-exact vs monolithic");
    push_case(
        &mut cases,
        CaseResult {
            name: "layer_pipeline",
            labels: ("monolithic_rps", "segmented_rps"),
            baseline_rps: mono.rps,
            treatment_rps: seg.rps,
            treatment_mean_batch: seg.mean_batch,
        },
    );
    // Heterogeneous leg: the same segmented stream on a calibrated
    // Pascal + Pavlov roster. The run itself asserts that >= 2
    // classes execute and that class boundaries charge transfer
    // windows; here we pin the cross-roster numerics.
    let (hetero, hetero_out, transfers) =
        run_pipeline_arm(dir, true, pipeline_roster(), &pipe_inputs);
    assert_eq!(hetero_out, mono_out, "cross-class pipeline must stay bit-exact");
    println!(
        "{:<24} segmented_rps {:>9.0} req/s | >= 2 classes | {transfers} transfers charged",
        "layer_pipeline_hetero", hetero.rps,
    );

    // Overload-protection comparison (PR 7 tentpole): one family at
    // ~4x its emulated service capacity, every request on a 6 ms
    // budget — `overload = "block"` vs `"shed"`. Blocking answers
    // everything eventually but almost every answer blows its budget;
    // admission + enqueue shedding refuses the unmeetable work up
    // front, so the requests that ARE served land inside their
    // budgets and both SLO attainment and goodput rise.
    let block = run_overload_arm(dir, &families[0], false);
    let shed = run_overload_arm(dir, &families[0], true);
    let overload = OverloadResult {
        block_slo: block.slo,
        shed_slo: shed.slo,
        slo_gain: shed.slo / block.slo.max(1.0 / OVERLOAD_REQUESTS as f64),
        block_goodput_rps: block.goodput_rps,
        shed_goodput_rps: shed.goodput_rps,
    };
    println!(
        "{:<24} block_slo {:>6.3} | shed_slo {:>6.3} | slo_gain {:.2}x | goodput {:.0} -> \
         {:.0} req/s",
        "overload_goodput",
        overload.block_slo,
        overload.shed_slo,
        overload.slo_gain,
        overload.block_goodput_rps,
        overload.shed_goodput_rps,
    );

    // Hierarchical-inference comparison (PR 7 tentpole): always-large
    // vs small-first with confidence-gated escalation. The threshold
    // sits at the probed median confidence, so ~half the requests
    // escalate and the hierarchical arm pays ~(1 + 16)/2 / 16 ≈ 0.53
    // of the always-large MACs.
    let esc_inputs = escalation_inputs();
    let threshold = probe_escalation_threshold(dir, &esc_inputs);
    let (large_rps, _, _) =
        run_escalation_arm(dir, "esc_large", escalation_config(threshold, false), &esc_inputs);
    let (hier_rps, hier_batch, escalated_frac) =
        run_escalation_arm(dir, "esc_small", escalation_config(threshold, true), &esc_inputs);
    let escalation = EscalationResult {
        always_large_rps: large_rps,
        hierarchical_rps: hier_rps,
        mean_batch: hier_batch,
        escalated_frac,
    };
    println!(
        "{:<24} always_large {:>9.0} req/s | hierarchical {:>9.0} req/s | speedup {:.2}x | \
         escalated {:.0}% (threshold {:.4})",
        "hier_escalation",
        escalation.always_large_rps,
        escalation.hierarchical_rps,
        escalation.speedup(),
        100.0 * escalation.escalated_frac,
        threshold,
    );

    // Fault-tolerance comparison (PR 8 tentpole): one family on a
    // calibrated two-class roster, paced at ~70% of the BACKUP
    // class's capacity. Healthy; the placed class blacked out with
    // retry + circuit-breaker failover armed; the same blackout with
    // recovery disabled. Arrivals are paced, so the goodput ratios
    // reduce to completed fractions: the breaker re-places the family
    // on the backup class, which absorbs the load, while the bare arm
    // fails every placed request.
    let (fo_roster, fo_primary) = failover_roster(&families[0]);
    let blackout = FaultPlan {
        seed: 0x0FA1,
        blackout_class: Some(fo_primary.clone()),
        ..FaultPlan::default()
    };
    let healthy_rps = run_failover_arm(dir, &families[0], fo_roster.clone(), None, true);
    let failover_rps =
        run_failover_arm(dir, &families[0], fo_roster.clone(), Some(blackout.clone()), true);
    let no_failover_rps = run_failover_arm(dir, &families[0], fo_roster, Some(blackout), false);
    let failover = FailoverResult { healthy_rps, failover_rps, no_failover_rps };
    println!(
        "{:<24} healthy {:>6.0} req/s | blackout+failover {:>6.0} req/s | blackout bare \
         {:>6.0} req/s | retention {:.3} | gain {:.1}x (blacked class: {fo_primary})",
        "degraded_failover",
        failover.healthy_rps,
        failover.failover_rps,
        failover.no_failover_rps,
        failover.retention(),
        failover.retention_gain(),
    );

    // Acceptance bars (printed, recorded in BENCH_serving.json).
    let headline = &cases[0];
    if headline.speedup() >= 2.0 {
        println!(
            "PASS: skewed-load stealing speedup {:.2}x >= 2x on {BENCH_WORKERS} workers",
            headline.speedup()
        );
    } else {
        println!(
            "WARN: skewed-load stealing speedup {:.2}x < 2x (host has few cores? see \
             skewed_device_emulated notes)",
            headline.speedup()
        );
    }
    let gemm = cases.iter().find(|c| c.name == "skewed_gemm").expect("gemm case");
    if gemm.speedup() > 1.0 && gemm_batch >= 4.0 {
        println!(
            "PASS: batched GEMM {:.2}x over per-sample at mean executed batch {gemm_batch:.1}",
            gemm.speedup()
        );
    } else {
        println!(
            "WARN: batched GEMM speedup {:.2}x (mean executed batch {gemm_batch:.1}) — \
             expected > 1x at batch >= 4",
            gemm.speedup()
        );
    }
    let reorder = cases.iter().find(|c| c.name == "hot_family_reorder").expect("reorder case");
    if reorder.speedup() > 1.0 {
        println!(
            "PASS: reorder buffer {:.2}x over family lease on the hot family (FIFO held)",
            reorder.speedup()
        );
    } else {
        println!(
            "WARN: reorder buffer speedup {:.2}x <= 1x on the hot-family case",
            reorder.speedup()
        );
    }
    let chunks = cases.iter().find(|c| c.name == "oversized_job_chunks").expect("chunk case");
    if chunks.speedup() > 1.0 {
        println!(
            "PASS: chunk-granular sequencing {:.2}x over job-granular on one oversized job",
            chunks.speedup()
        );
    } else {
        println!(
            "WARN: chunk-granular speedup {:.2}x <= 1x on the oversized-job case",
            chunks.speedup()
        );
    }
    let adaptive = cases.iter().find(|c| c.name == "adaptive_depth").expect("adaptive case");
    if adaptive.speedup() > 1.0 {
        println!(
            "PASS: adaptive depth {:.2}x over the static lease under shifting skew",
            adaptive.speedup()
        );
    } else {
        println!(
            "WARN: adaptive depth speedup {:.2}x <= 1x under shifting skew",
            adaptive.speedup()
        );
    }
    let placement = cases.iter().find(|c| c.name == "mensa_placement").expect("placement case");
    if placement.speedup() > 1.0 {
        println!(
            "PASS: Mensa placement {:.2}x over the homogeneous roster on the skewed mix",
            placement.speedup()
        );
    } else {
        println!(
            "WARN: Mensa placement speedup {:.2}x <= 1x over the homogeneous roster",
            placement.speedup()
        );
    }
    let pipe = cases.iter().find(|c| c.name == "layer_pipeline").expect("pipeline case");
    if pipe.speedup() > 1.0 {
        println!(
            "PASS: layer pipeline {:.2}x over the monolithic lease on a single hot stream",
            pipe.speedup()
        );
    } else {
        println!(
            "WARN: layer pipeline speedup {:.2}x <= 1x on the single-stream case",
            pipe.speedup()
        );
    }
    if overload.slo_gain > 1.0 && overload.shed_slo > overload.block_slo {
        println!(
            "PASS: shedding lifts SLO attainment {:.3} -> {:.3} ({:.2}x) at ~4x offered load",
            overload.block_slo, overload.shed_slo, overload.slo_gain
        );
    } else {
        println!(
            "WARN: shed-arm SLO attainment {:.3} <= block arm's {:.3} under overload",
            overload.shed_slo, overload.block_slo
        );
    }
    if escalation.speedup() > 1.0 && escalation.escalated_frac > 0.0 {
        println!(
            "PASS: hierarchical escalation {:.2}x over always-large at {:.0}% escalated",
            escalation.speedup(),
            100.0 * escalation.escalated_frac
        );
    } else {
        println!(
            "WARN: hierarchical escalation {:.2}x (escalated {:.0}%) — expected > 1x with a \
             partial escalation rate",
            escalation.speedup(),
            100.0 * escalation.escalated_frac
        );
    }
    if failover.retention() >= 0.5 && failover.retention_gain() > 1.0 {
        println!(
            "PASS: breaker failover retains {:.0}% of healthy goodput under a class blackout \
             (bare arm: {:.0}%)",
            100.0 * failover.retention(),
            100.0 * failover.no_failover_retention(),
        );
    } else {
        println!(
            "WARN: failover goodput retention {:.2} (gain {:.1}x) — expected >= 0.5 with the \
             backup class absorbing the load",
            failover.retention(),
            failover.retention_gain(),
        );
    }
    ServingResult { cases, overload, escalation, failover }
}

fn push_case(cases: &mut Vec<CaseResult>, case: CaseResult) {
    println!(
        "{:<24} {} {:>9.0} req/s | {} {:>9.0} req/s | speedup {:.2}x | mean batch {:.1}",
        case.name,
        case.labels.0,
        case.baseline_rps,
        case.labels.1,
        case.treatment_rps,
        case.speedup(),
        case.treatment_mean_batch,
    );
    cases.push(case);
}

fn write_bench_json(
    kernel: &KernelResult,
    gemm: &GemmResult,
    packed: &PackedResult,
    simd: &SimdResult,
    quant: &QuantizedResult,
    serving: &ServingResult,
) {
    let mut json = String::from("{\n  \"bench\": \"serving_throughput\",\n");
    let _ = write!(
        json,
        "  \"workers\": {BENCH_WORKERS},\n  \"families\": {BENCH_FAMILIES},\n  \
         \"requests\": {BENCH_REQUESTS},\n"
    );
    for case in &serving.cases {
        let _ = write!(
            json,
            "  \"{}\": {{\"{}\": {:.1}, \"{}\": {:.1}, \"speedup\": {:.3}, \
             \"mean_batch\": {:.2}}},\n",
            case.name,
            case.labels.0,
            case.baseline_rps,
            case.labels.1,
            case.treatment_rps,
            case.speedup(),
            case.treatment_mean_batch,
        );
    }
    let o = &serving.overload;
    let _ = write!(
        json,
        "  \"overload_goodput\": {{\"block_slo\": {:.4}, \"shed_slo\": {:.4}, \
         \"slo_gain\": {:.3}, \"block_goodput_rps\": {:.1}, \"shed_goodput_rps\": {:.1}}},\n",
        o.block_slo,
        o.shed_slo,
        o.slo_gain,
        o.block_goodput_rps,
        o.shed_goodput_rps
    );
    let e = &serving.escalation;
    let _ = write!(
        json,
        "  \"hier_escalation\": {{\"always_large_rps\": {:.1}, \"hierarchical_rps\": {:.1}, \
         \"speedup\": {:.3}, \"escalated_frac\": {:.4}, \"mean_batch\": {:.2}}},\n",
        e.always_large_rps,
        e.hierarchical_rps,
        e.speedup(),
        e.escalated_frac,
        e.mean_batch
    );
    let fo = &serving.failover;
    let _ = write!(
        json,
        "  \"degraded_failover\": {{\"healthy_rps\": {:.1}, \"failover_rps\": {:.1}, \
         \"no_failover_rps\": {:.1}, \"retention\": {:.4}, \"retention_gain\": {:.3}}},\n",
        fo.healthy_rps,
        fo.failover_rps,
        fo.no_failover_rps,
        fo.retention(),
        fo.retention_gain()
    );
    let _ = write!(
        json,
        "  \"gemm_dense\": {{\"per_sample_ns_per_sample\": {:.1}, \
         \"batched_ns_per_sample\": {:.1}, \"speedup\": {:.3}}},\n",
        gemm.per_sample_ns_per_sample,
        gemm.batched_ns_per_sample,
        gemm.per_sample_ns_per_sample / gemm.batched_ns_per_sample.max(1e-9)
    );
    let _ = write!(
        json,
        "  \"packed_panels\": {{\"row_major_ns_per_sample\": {:.1}, \
         \"packed_ns_per_sample\": {:.1}, \"speedup\": {:.3}}},\n",
        packed.row_major_ns_per_sample,
        packed.packed_ns_per_sample,
        packed.row_major_ns_per_sample / packed.packed_ns_per_sample.max(1e-9)
    );
    let _ = write!(
        json,
        "  \"simd_kernel\": {{\"scalar_ns_per_sample\": {:.1}, \
         \"simd_ns_per_sample\": {:.1}, \"speedup\": {:.3}}},\n",
        simd.scalar_ns_per_sample,
        simd.simd_ns_per_sample,
        simd.scalar_ns_per_sample / simd.simd_ns_per_sample.max(1e-9)
    );
    let _ = write!(
        json,
        "  \"quantized_gemm\": {{\"f32_ns_per_sample\": {:.1}, \"i8_ns_per_sample\": {:.1}, \
         \"speedup\": {:.3}, \"recurrent_f32_ns_per_sample\": {:.1}, \
         \"recurrent_i8_ns_per_sample\": {:.1}, \"recurrent_speedup\": {:.3}, \
         \"f32_bytes_per_mac\": {:.4}, \"i8_bytes_per_mac\": {:.4}}},\n",
        quant.dense_f32_ns_per_sample,
        quant.dense_i8_ns_per_sample,
        quant.speedup(),
        quant.recurrent_f32_ns_per_sample,
        quant.recurrent_i8_ns_per_sample,
        quant.recurrent_speedup(),
        quant.f32_bytes_per_mac,
        quant.i8_bytes_per_mac
    );
    let _ = write!(
        json,
        "  \"kernel_dense\": {{\"naive_ns_per_sample\": {:.1}, \"blocked_ns_per_sample\": {:.1}, \
         \"speedup\": {:.3}}}\n}}\n",
        kernel.naive_ns_per_sample,
        kernel.blocked_ns_per_sample,
        kernel.naive_ns_per_sample / kernel.blocked_ns_per_sample.max(1e-9)
    );
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serving.json");
    std::fs::write(path, &json).expect("write BENCH_serving.json");
    println!("wrote {path}");
}
