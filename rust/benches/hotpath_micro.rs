//! `cargo bench --bench hotpath_micro` — L3 hot-path microbenchmarks
//! for the §Perf optimization pass (EXPERIMENTS.md).
//!
//! Measures the three operations on the coordinator's critical path:
//! the per-layer dataflow cost model (invoked O(layers x accels) per
//! schedule), the two-phase scheduler, and a full simulator run — plus
//! the whole 24x4 evaluation grid as the end-to-end macro number.

use mensa::accel::configs;
use mensa::bench_harness::timer;
use mensa::model::zoo;
use mensa::scheduler::{Mapping, MensaScheduler, ScheduleCache};
use mensa::sim::Simulator;
use std::hint::black_box;

fn main() {
    timer::header("hotpath_micro");
    let baseline = configs::edge_tpu_baseline();
    let mensa = configs::mensa_g();
    let cnn = zoo::cnn(0);
    let lstm = zoo::lstm(0);

    // 1. Dataflow cost model, per layer (the innermost hot function).
    let layer = &cnn.layers()[5];
    let m = timer::bench("dataflow_cost/conv_layer", 20, 10_000, || {
        black_box(baseline.dataflow.cost(&baseline, black_box(layer)));
    });
    println!("{}", m.render());
    let gate = lstm
        .layers()
        .iter()
        .find(|l| l.name.contains("gate"))
        .expect("lstm gate");
    let m = timer::bench("dataflow_cost/lstm_gate", 20, 10_000, || {
        black_box(mensa.accels[1].dataflow.cost(&mensa.accels[1], black_box(gate)));
    });
    println!("{}", m.render());

    // 2. Scheduler: full two-phase schedule of one model.
    let scheduler = MensaScheduler::new(&mensa);
    let m = timer::bench("scheduler/cnn_schedule", 10, 200, || {
        black_box(scheduler.schedule(black_box(&cnn)));
    });
    println!("{}", m.render());
    let m = timer::bench("scheduler/lstm_schedule", 10, 200, || {
        black_box(scheduler.schedule(black_box(&lstm)));
    });
    println!("{}", m.render());

    // 3. Simulator: one inference end to end.
    let sim = Simulator::new(&mensa);
    let mapping = scheduler.schedule(&cnn);
    let m = timer::bench("simulator/cnn_run", 10, 200, || {
        black_box(sim.run(black_box(&cnn), black_box(&mapping)));
    });
    println!("{}", m.render());
    let base_sys = configs::baseline_system();
    let base_sim = Simulator::new(&base_sys);
    let base_map = Mapping::uniform(lstm.len(), 0);
    let m = timer::bench("simulator/lstm_run_baseline", 10, 200, || {
        black_box(base_sim.run(black_box(&lstm), black_box(&base_map)));
    });
    println!("{}", m.render());

    // 4. ScheduleCache: the serving path's family_sim_costs()
    // equivalent — cold (schedule + simulate) vs a warm cache hit.
    // Acceptance bar: the hit must be >= 10x faster than the cold
    // path (it is typically orders of magnitude).
    let cold = timer::bench("schedule_cache/cold_miss", 5, 5, || {
        let cache = ScheduleCache::new();
        black_box(cache.get_or_compute(black_box(&mensa), black_box(&cnn)));
    });
    println!("{}", cold.render());
    let warm_cache = ScheduleCache::new();
    warm_cache.get_or_compute(&mensa, &cnn);
    let warm = timer::bench("schedule_cache/warm_hit", 20, 2_000, || {
        black_box(warm_cache.get_or_compute(black_box(&mensa), black_box(&cnn)));
    });
    println!("{}", warm.render());
    println!(
        "schedule_cache speedup: {:.0}x (cold {:.0} ns -> hit {:.0} ns)",
        cold.mean_ns / warm.mean_ns.max(1.0),
        cold.mean_ns,
        warm.mean_ns
    );

    // 5. Macro: the full 24-model x 4-system evaluation grid.
    let m = timer::bench("grid/24x4_evaluation", 3, 2, || {
        black_box(mensa::bench_harness::evaluation::evaluation_grid());
    });
    println!("{}", m.render());
}
