//! `cargo bench --bench fig1_rooflines` — regenerates the Fig. 1 throughput + energy rooflines
//! and times the underlying computation (criterion is unavailable
//! offline; see bench_harness::timer).

use mensa::bench_harness::{run_experiment, timer};

fn main() {
    timer::header("fig1_rooflines");
    for id in ["fig1-throughput", "fig1-energy"] {
        let report = run_experiment(id).expect("experiment");
        println!("{report}");
        let m = timer::bench(id, 5, 2, || {
            std::hint::black_box(run_experiment(id).unwrap());
        });
        println!("{}", m.render());
    }
}
