//! `cargo bench --bench fig6_families` — regenerates Fig. 6 (five-family clustering)
//! and times the underlying computation (criterion is unavailable
//! offline; see bench_harness::timer).

use mensa::bench_harness::{run_experiment, timer};

fn main() {
    timer::header("fig6_families");
    for id in ["fig6"] {
        let report = run_experiment(id).expect("experiment");
        println!("{report}");
        let m = timer::bench(id, 5, 2, || {
            std::hint::black_box(run_experiment(id).unwrap());
        });
        println!("{}", m.render());
    }
}
