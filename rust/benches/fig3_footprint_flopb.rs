//! `cargo bench --bench fig3_footprint_flopb` — regenerates Fig. 3 (gate footprints, footprint vs FLOP/B)
//! and times the underlying computation (criterion is unavailable
//! offline; see bench_harness::timer).

use mensa::bench_harness::{run_experiment, timer};

fn main() {
    timer::header("fig3_footprint_flopb");
    for id in ["fig3"] {
        let report = run_experiment(id).expect("experiment");
        println!("{report}");
        let m = timer::bench(id, 5, 2, || {
            std::hint::black_box(run_experiment(id).unwrap());
        });
        println!("{}", m.render());
    }
}
