//! `cargo bench --bench fig10_energy` — regenerates Fig. 10 (inference energy, 4 systems + Mensa-G accel split)
//! and times the underlying computation (criterion is unavailable
//! offline; see bench_harness::timer).

use mensa::bench_harness::{run_experiment, timer};

fn main() {
    timer::header("fig10_energy");
    for id in ["fig10-energy", "fig10-accel"] {
        let report = run_experiment(id).expect("experiment");
        println!("{report}");
        let m = timer::bench(id, 5, 2, || {
            std::hint::black_box(run_experiment(id).unwrap());
        });
        println!("{}", m.render());
    }
}
