//! `cargo bench --bench ablate_pe_size` — regenerates the §5.3-5.5 PE-array sizing sweeps
//! and times the underlying computation (criterion is unavailable
//! offline; see bench_harness::timer).

use mensa::bench_harness::{run_experiment, timer};

fn main() {
    timer::header("ablate_pe_size");
    for id in ["tab-pe-sweep"] {
        let report = run_experiment(id).expect("experiment");
        println!("{report}");
        let m = timer::bench(id, 5, 2, || {
            std::hint::black_box(run_experiment(id).unwrap());
        });
        println!("{}", m.render());
    }
}
