//! `cargo bench --bench fig45_layer_diversity` — regenerates Figs. 4-5 (per-layer MAC/footprint diversity)
//! and times the underlying computation (criterion is unavailable
//! offline; see bench_harness::timer).

use mensa::bench_harness::{run_experiment, timer};

fn main() {
    timer::header("fig45_layer_diversity");
    for id in ["fig4", "fig5"] {
        let report = run_experiment(id).expect("experiment");
        println!("{report}");
        let m = timer::bench(id, 5, 2, || {
            std::hint::black_box(run_experiment(id).unwrap());
        });
        println!("{}", m.render());
    }
}
