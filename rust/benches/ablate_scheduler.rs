//! `cargo bench --bench ablate_scheduler` — regenerates the scheduler-quality + accelerator-count ablations
//! and times the underlying computation (criterion is unavailable
//! offline; see bench_harness::timer).

use mensa::bench_harness::{run_experiment, timer};

fn main() {
    timer::header("ablate_scheduler");
    for id in ["tab-sched"] {
        let report = run_experiment(id).expect("experiment");
        println!("{report}");
        let m = timer::bench(id, 5, 2, || {
            std::hint::black_box(run_experiment(id).unwrap());
        });
        println!("{}", m.render());
    }
}
