//! `cargo bench --bench ablate_buffer` — regenerates the §3.1 8x-buffer-capacity study
//! and times the underlying computation (criterion is unavailable
//! offline; see bench_harness::timer).

use mensa::bench_harness::{run_experiment, timer};

fn main() {
    timer::header("ablate_buffer");
    for id in ["tab-buffer8x"] {
        let report = run_experiment(id).expect("experiment");
        println!("{report}");
        let m = timer::bench(id, 5, 2, || {
            std::hint::black_box(run_experiment(id).unwrap());
        });
        println!("{}", m.render());
    }
}
