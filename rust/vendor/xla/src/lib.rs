//! Offline API stub for the `xla` crate.
//!
//! The real `xla` crate links the native XLA/PJRT libraries and cannot
//! be vendored into the offline build image. This stub reproduces the
//! *API surface* `runtime::pjrt` compiles against — client creation,
//! HLO parsing, compilation, execution, literal conversion — so the
//! feature-gated PJRT backend type-checks, lints, and stays wired into
//! the `runtime::Backend` seam without the native toolchain.
//!
//! Every constructor that would touch native code returns
//! [`Error::Unavailable`]: a `--features pjrt` build *runs*, but
//! `PjRtClient::cpu()` fails at load time with a clear message instead
//! of executing anything. Swapping in the real crate (same package
//! name, path or registry) restores native execution with no source
//! changes in `runtime::pjrt`.
//!
//! All types here are plain owned data, so they are `Send + Sync` —
//! which is what lets the shared-`Arc<Runtime>` executor pool (and the
//! `runtime::Backend` trait's `Send + Sync` supertrait) compile under
//! the feature. A real PJRT client must uphold the same bound to join
//! the pool.

use std::fmt;

/// Stub error: the native XLA/PJRT libraries are not linked.
#[derive(Debug)]
pub enum Error {
    /// The operation needs the real `xla` crate.
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what}: the vendored `xla` stub has no native XLA/PJRT \
                 libraries (swap in the real crate to execute)"
            ),
        }
    }
}

impl std::error::Error for Error {}

/// Stub `Result` alias matching the real crate's fallible API.
pub type Result<T> = std::result::Result<T, Error>;

/// A parsed HLO module (stub: never constructed successfully).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an HLO text file. Always fails in the stub.
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(Error::Unavailable("parsing HLO text"))
    }
}

/// A computation ready to compile.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a parsed module (infallible in the real crate too).
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self { _private: () }
    }
}

/// A PJRT client (stub: construction always fails).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Create a CPU client. Always fails in the stub — this is the
    /// load-time error a `--features pjrt` build surfaces.
    pub fn cpu() -> Result<Self> {
        Err(Error::Unavailable("creating PJRT CPU client"))
    }

    /// The backing platform's name.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation for this client.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("compiling computation"))
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with one argument list; returns per-device, per-output
    /// buffers.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("executing"))
    }
}

/// A device buffer holding one execution output.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Fetch the buffer to the host as a literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("fetching result"))
    }
}

/// A host-side tensor literal.
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1(_data: &[f32]) -> Self {
        Self { _private: () }
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::Unavailable("reshaping literal"))
    }

    /// Unwrap a 1-tuple literal.
    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(Error::Unavailable("unwrapping tuple"))
    }

    /// Copy out as a typed host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::Unavailable("converting literal"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_surfaces_unavailable_errors() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let e = Error::Unavailable("doing something");
        assert!(e.to_string().contains("stub"), "{e}");
    }

    #[test]
    fn stub_types_are_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<PjRtClient>();
        assert_send_sync::<PjRtLoadedExecutable>();
        assert_send_sync::<Literal>();
    }
}
