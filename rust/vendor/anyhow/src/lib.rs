//! Offline stand-in for the `anyhow` crate.
//!
//! The build image has no crates.io access, so this vendored shim
//! provides the subset of the `anyhow` API the workspace uses:
//!
//! * [`Error`] — an opaque error carrying a flattened message chain
//!   (`context: context: root cause`). Unlike real `anyhow`, there is
//!   no downcasting or backtrace capture; converting a source error
//!   eagerly folds its `source()` chain into the message.
//! * [`Result`] — `Result<T, Error>` with a defaulted error type.
//! * [`anyhow!`] / [`bail!`] — formatted construction / early return.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result`
//!   (any `std::error::Error` *or* an [`Error`]) and on `Option`.
//!
//! Formatting matches what the test-suite asserts on: both `{e}` and
//! `{e:#}` render the full `outer: inner: root` chain, so substring
//! checks written against real `anyhow`'s `{:#}` output keep passing.
//!
//! The coherence structure (the private [`ext::StdError`] helper trait
//! with a blanket impl for `std::error::Error` types plus a concrete
//! impl for [`Error`], which itself deliberately does **not** implement
//! `std::error::Error`) mirrors real `anyhow`, which is what makes the
//! blanket `From` conversion and the `Context` impls coexist on stable.

use core::fmt::{self, Display};

/// An opaque error: a flattened, `': '`-joined message chain.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable.
    pub fn msg(message: impl Display) -> Self {
        Error { msg: message.to_string() }
    }

    /// Prepend a context layer (`context: current`).
    fn wrap(self, context: impl Display) -> Self {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{e}` and `{e:#}` both render the full chain (see module doc).
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: `Error` intentionally does NOT implement `std::error::Error`;
// that absence is what makes this blanket impl coherent (same trick as
// real `anyhow`).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Self {
        let mut msg = err.to_string();
        let mut source = err.source();
        while let Some(s) = source {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            source = s.source();
        }
        Error { msg }
    }
}

/// `Result` with the error type defaulted to [`Error`].
pub type Result<T, E = Error> = core::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return a formatted [`Error`] unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

mod ext {
    use super::Error;
    use core::fmt::Display;

    /// Private helper: "anything that can become an [`Error`] while
    /// absorbing a context layer".
    pub trait StdError {
        fn ext_context<C: Display>(self, context: C) -> Error;
    }

    impl<E> StdError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn ext_context<C: Display>(self, context: C) -> Error {
            Error::from(self).wrap(context)
        }
    }

    impl StdError for Error {
        fn ext_context<C: Display>(self, context: C) -> Error {
            self.wrap(context)
        }
    }
}

/// Attach context to errors, `anyhow`-style.
pub trait Context<T, E> {
    /// Wrap the error with a context message.
    fn context<C: Display>(self, context: C) -> Result<T, Error>;

    /// Wrap the error with a lazily-evaluated context message.
    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: ext::StdError + Send + Sync + 'static,
{
    fn context<C: Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.ext_context(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.ext_context(f()))
    }
}

impl<T> Context<T, core::convert::Infallible> for Option<T> {
    fn context<C: Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn macro_formats() {
        let e = anyhow!("bad {} of {}", "kind", 7);
        assert_eq!(e.to_string(), "bad kind of 7");
    }

    #[test]
    fn bail_returns_early() {
        fn f(x: i32) -> Result<i32> {
            if x < 0 {
                bail!("negative: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(-1).unwrap_err().to_string().contains("negative"));
    }

    #[test]
    fn context_chains_render_in_both_formats() {
        let r: Result<()> = Err(io_err()).context("reading manifest");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest: missing thing");
        assert_eq!(format!("{e:#}"), "reading manifest: missing thing");
    }

    #[test]
    fn with_context_on_anyhow_error_and_option() {
        let base: Result<()> = Err(anyhow!("root"));
        let e = base.with_context(|| format!("layer {}", 2)).unwrap_err();
        assert_eq!(e.to_string(), "layer 2: root");
        let none: Option<u8> = None;
        let e = none.context("nothing there").unwrap_err();
        assert_eq!(e.to_string(), "nothing there");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<u32> {
            let n: u32 = "12x".parse()?;
            Ok(n)
        }
        assert!(f().unwrap_err().to_string().contains("invalid digit"));
    }

    #[test]
    fn ensure_macro() {
        fn f(x: i32) -> Result<()> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(())
        }
        assert!(f(1).is_ok());
        assert!(f(0).unwrap_err().to_string().contains("positive"));
    }
}
