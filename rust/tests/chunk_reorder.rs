//! Chunk-granular reorder contracts, end to end through the server:
//!
//! * **oversized-job spreading** — one flush larger than the family's
//!   biggest compiled variant splits into capacity chunks *in the
//!   batcher*, and with `reorder_depth >= 2` those chunks execute on
//!   several workers concurrently (the single job that used to pin one
//!   worker now uses the pool), while clients still observe strict
//!   FIFO (`fifo_violations == 0`, responses bit-exact vs solo runs);
//! * **per-chunk panic isolation** — a kernel panicking mid-job (the
//!   `panic_on_poison` runtime hook) errors only its own chunk's
//!   requests, fills its reorder slot, and leaves sibling chunks of
//!   the same flush delivering in order;
//! * **FIFO via the metrics snapshot** — a sustained hot-family flood
//!   through the public server API keeps `Snapshot::fifo_violations`
//!   at 0 (previously asserted only inside the bench binary);
//! * **adaptive depth** — with `reorder_depth_max`, a backlogged
//!   family widens beyond the lease while a cold family stays at depth
//!   1 (`Snapshot::depth_by_family`), and a formerly hot family
//!   **narrows back to the single-holder lease after its backlog
//!   drains, without any new pushes** — pops and releases fold drain
//!   samples into the depth EWMA
//!   (`Snapshot::current_depth_by_family`).

use mensa::config::ServerConfig;
use mensa::coordinator::Server;
use mensa::runtime::POISON_INPUT;
use mensa::util::rng::Rng;
use std::time::{Duration, Instant};

const TIMEOUT: Duration = Duration::from_secs(30);

fn artifacts_dir() -> Option<String> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(&format!("{dir}/manifest.toml")).exists() {
        Some(dir.to_string())
    } else {
        eprintln!("SKIP: no artifacts; run `make artifacts`");
        None
    }
}

fn cnn_input(rng: &mut Rng) -> Vec<f32> {
    (0..32 * 32 * 3).map(|_| rng.range_f64(0.0, 1.0) as f32).collect()
}

fn lstm_input(rng: &mut Rng) -> Vec<f32> {
    (0..8 * 128).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect()
}

/// Solo (batch-1) outputs from a fresh default server — the bit-exact
/// reference every flooded response must reproduce.
fn solo_outputs(dir: &str, family: &str, inputs: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let server = Server::start(dir, ServerConfig::default()).expect("solo server");
    let out = inputs
        .iter()
        .map(|x| server.infer_blocking(family, vec![x.clone()], TIMEOUT).unwrap().output)
        .collect();
    server.shutdown();
    out
}

#[test]
fn oversized_single_job_spreads_chunks_across_workers() {
    let Some(dir) = artifacts_dir() else { return };
    // edge_lstm tops out at b4: a single 16-request flush is one job
    // of four chunks. Per-chunk emulated device time is the overlap
    // discriminator: any discipline that runs the job's chunks
    // front-to-back on one worker (the old job-granular path, or the
    // lease) pays 4 x 50 ms of device sleep before the last delivery,
    // while chunk-granular dispatch on 4 workers overlaps the sleeps —
    // and deliveries happen *before* each chunk's device window, so
    // the flood bound below is only reachable when the chunks truly
    // ran concurrently. Deliveries precede each chunk's device
    // window, so the front-to-back floor for the LAST delivery is
    // three full device sleeps (~150 ms) while the concurrent path
    // delivers after zero sleeps (the compute is sub-millisecond and
    // sleeps overlap regardless of host core count): the 100 ms bound
    // sits ~100 ms above the parallel path — slack for a loaded CI
    // runner with this binary's other tests in flight — and a full
    // device window under the serial floor.
    const DEVICE: Duration = Duration::from_millis(50);
    let cfg = ServerConfig {
        workers: 4,
        max_batch: 16,
        batch_timeout_us: 200_000,
        work_stealing: true,
        reorder_depth: 4,
        device_latency_us: DEVICE.as_micros() as u64,
        ..Default::default()
    };
    let mut rng = Rng::new(0xC4A1);
    let inputs: Vec<Vec<f32>> = (0..16).map(|_| lstm_input(&mut rng)).collect();
    let solo = solo_outputs(&dir, "edge_lstm", &inputs);

    let server = Server::start(&dir, cfg).expect("start");
    let t0 = Instant::now();
    let rxs: Vec<_> = inputs
        .iter()
        .map(|x| server.infer_request("edge_lstm", vec![x.clone()]).send().expect("submit"))
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv_timeout(TIMEOUT).expect("recv").expect("ok");
        assert!(resp.batch_size <= 4, "chunk exceeds largest variant");
        assert_eq!(resp.output, solo[i], "request {i} bit-exact through chunk spreading");
    }
    let flood_wall = t0.elapsed();
    assert!(
        flood_wall < DEVICE * 2,
        "flood took {flood_wall:?} — the oversized job's chunks did not overlap \
         (front-to-back delivery floor is {:?}; concurrent chunks deliver before \
         any device sleep elapses)",
        DEVICE * 3
    );
    let snap = server.metrics();
    assert_eq!(snap.completed, 16);
    assert_eq!(snap.failed, 0);
    assert_eq!(snap.fifo_violations, 0, "clients must observe strict FIFO");
    assert_eq!(snap.jobs, 4, "one 16-request flush executes as four b4 chunks");
    let workers_seen = snap
        .workers_by_family
        .iter()
        .find(|(f, _)| f == "edge_lstm")
        .map(|(_, ws)| ws.clone())
        .unwrap_or_default();
    assert!(
        workers_seen.len() >= 2,
        "a single oversized job must execute on several workers, saw {workers_seen:?}"
    );
    server.shutdown();
}

#[test]
fn poisoned_chunk_errors_only_its_own_requests() {
    let Some(dir) = artifacts_dir() else { return };
    // 16 lstm requests flush as chunks [0..4), [4..8), [8..12),
    // [12..16); request 5 carries the poison sentinel, so chunk 1's
    // kernel panics mid-job while three sibling chunks of the SAME
    // flush execute on other workers.
    let mut rng = Rng::new(0xDEAD);
    let mut inputs: Vec<Vec<f32>> = (0..16).map(|_| lstm_input(&mut rng)).collect();
    let solo = solo_outputs(&dir, "edge_lstm", &inputs);
    inputs[5][0] = POISON_INPUT;

    let cfg = ServerConfig {
        workers: 4,
        max_batch: 16,
        batch_timeout_us: 200_000,
        work_stealing: true,
        reorder_depth: 4,
        panic_on_poison: true,
        ..Default::default()
    };
    let server = Server::start(&dir, cfg).expect("start");
    let rxs: Vec<_> = inputs
        .iter()
        .map(|x| server.infer_request("edge_lstm", vec![x.clone()]).send().expect("submit"))
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let result = rx.recv_timeout(TIMEOUT).expect("every request gets a reply");
        if (4..8).contains(&i) {
            let err = result.expect_err("poisoned chunk's member must error");
            assert!(
                format!("{err:#}").contains("panicked"),
                "request {i}: expected the caught panic, got {err:#}"
            );
        } else {
            let resp = result.unwrap_or_else(|e| {
                panic!("request {i} outside the poisoned chunk failed: {e:#}")
            });
            assert_eq!(resp.output, solo[i], "sibling chunk request {i} bit-exact");
        }
    }
    let snap = server.metrics();
    assert_eq!(snap.failed, 4, "exactly the poisoned chunk's requests fail");
    assert_eq!(snap.completed, 12, "sibling chunks all deliver");
    assert_eq!(snap.fifo_violations, 0, "the failed slot must not break ordering");
    assert_eq!(
        snap.jobs_panicked, 1,
        "one caught panic, attributed to exactly one chunk — the counter that \
         distinguishes a panic from an ordinary input error"
    );
    // Server stays healthy after the panic.
    let mut rng = Rng::new(0xBEEF);
    let x = lstm_input(&mut rng);
    server.infer_blocking("edge_lstm", vec![x], TIMEOUT).expect("healthy after panic");
    server.shutdown();
}

#[test]
fn hot_family_flood_keeps_fifo_metric_clean_through_server_api() {
    let Some(dir) = artifacts_dir() else { return };
    // Sustained hot-family load with many small overlapping jobs: the
    // reorder path's FIFO contract asserted where it is observable —
    // the server's Metrics snapshot (previously only the bench binary
    // checked this).
    let mut rng = Rng::new(0xF1F0_4);
    let inputs: Vec<Vec<f32>> = (0..32).map(|_| cnn_input(&mut rng)).collect();
    let solo = solo_outputs(&dir, "edge_cnn", &inputs);

    let cfg = ServerConfig {
        workers: 4,
        max_batch: 2,
        batch_timeout_us: 1_000,
        work_stealing: true,
        reorder_depth: 4,
        device_latency_us: 5_000,
        ..Default::default()
    };
    let server = Server::start(&dir, cfg).expect("start");
    let rxs: Vec<_> = inputs
        .iter()
        .map(|x| {
            // Retry backpressure (queue depth is finite under a flood).
            loop {
                match server.infer_request("edge_cnn", vec![x.clone()]).send() {
                    Ok(rx) => return rx,
                    Err(_) => std::thread::sleep(Duration::from_micros(200)),
                }
            }
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv_timeout(TIMEOUT).expect("recv").expect("ok");
        assert_eq!(resp.output, solo[i], "request {i}: reorder path must stay in order");
    }
    let snap = server.metrics();
    assert_eq!(snap.fifo_violations, 0, "Metrics snapshot is the FIFO witness");
    assert_eq!(snap.failed, 0);
    assert_eq!(snap.completed, 32);
    let workers_seen = snap
        .workers_by_family
        .iter()
        .find(|(f, _)| f == "edge_cnn")
        .map(|(_, ws)| ws.clone())
        .unwrap_or_default();
    assert!(
        workers_seen.len() >= 2,
        "the hot family must use several workers, saw {workers_seen:?}"
    );
    server.shutdown();
}

#[test]
fn adaptive_depth_widens_hot_family_and_keeps_cold_family_leased() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rng = Rng::new(0xADA7);
    let hot: Vec<Vec<f32>> = (0..24).map(|_| cnn_input(&mut rng)).collect();
    let cold = lstm_input(&mut rng);
    let solo_hot = solo_outputs(&dir, "edge_cnn", &hot);
    let solo_cold = solo_outputs(&dir, "edge_lstm", std::slice::from_ref(&cold));

    // Adaptive policy: depth follows the backlog EWMA, clamped at 4.
    // Small batches + per-job device time make the hot family's queue
    // build, so its granted depth must widen; the single cold request
    // never sees a backlog and must stay at the lease depth of 1.
    let cfg = ServerConfig {
        workers: 4,
        max_batch: 2,
        batch_timeout_us: 1_000,
        work_stealing: true,
        reorder_depth_max: 4,
        device_latency_us: 10_000,
        ..Default::default()
    };
    let server = Server::start(&dir, cfg).expect("start");
    let cold_resp = server
        .infer_blocking("edge_lstm", vec![cold.clone()], TIMEOUT)
        .expect("cold request");
    assert_eq!(cold_resp.output, solo_cold[0], "cold family bit-exact");
    let rxs: Vec<_> = hot
        .iter()
        .map(|x| {
            loop {
                match server.infer_request("edge_cnn", vec![x.clone()]).send() {
                    Ok(rx) => return rx,
                    Err(_) => std::thread::sleep(Duration::from_micros(200)),
                }
            }
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv_timeout(TIMEOUT).expect("recv").expect("ok");
        assert_eq!(resp.output, solo_hot[i], "request {i} bit-exact under adaptive depth");
    }
    let snap = server.metrics();
    assert_eq!(snap.fifo_violations, 0);
    assert_eq!(snap.failed, 0);
    let depth = |family: &str| {
        snap.depth_by_family
            .iter()
            .find(|(f, _)| f == family)
            .map(|(_, d)| *d)
            .unwrap_or(0)
    };
    assert!(
        depth("edge_cnn") >= 2,
        "the backlogged family must widen beyond the lease, gauges: {:?}",
        snap.depth_by_family
    );
    assert_eq!(
        depth("edge_lstm"),
        1,
        "a cold family must keep the lease discipline, gauges: {:?}",
        snap.depth_by_family
    );
    server.shutdown();
}

#[test]
fn adaptive_depth_narrows_after_backlog_drains_without_new_pushes() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rng = Rng::new(0xD2A1);
    let hot: Vec<Vec<f32>> = (0..32).map(|_| cnn_input(&mut rng)).collect();
    let solo = solo_outputs(&dir, "edge_cnn", &hot);

    // Same shape of load as the widening test: small batches + per-job
    // device time build a backlog, so the hot family's granted depth
    // widens. Then the flood simply *stops* — every response below is
    // received, so the backlog is fully drained — and the decay-on-pop
    // EWMA plus the full-drain release must return the family to the
    // single-holder lease without a single further push.
    let cfg = ServerConfig {
        workers: 4,
        max_batch: 2,
        batch_timeout_us: 1_000,
        work_stealing: true,
        reorder_depth_max: 4,
        device_latency_us: 5_000,
        ..Default::default()
    };
    let server = Server::start(&dir, cfg).expect("start");
    let rxs: Vec<_> = hot
        .iter()
        .map(|x| {
            loop {
                match server.infer_request("edge_cnn", vec![x.clone()]).send() {
                    Ok(rx) => return rx,
                    Err(_) => std::thread::sleep(Duration::from_micros(200)),
                }
            }
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv_timeout(TIMEOUT).expect("recv").expect("ok");
        assert_eq!(resp.output, solo[i], "request {i} bit-exact");
    }
    // All responses are in, but the last holders may still be inside
    // their emulated device windows; give them time to release (the
    // release is what folds the final zero-backlog samples and resets
    // a fully drained family).
    std::thread::sleep(Duration::from_millis(200));
    let snap = server.metrics();
    assert_eq!(snap.fifo_violations, 0);
    assert_eq!(snap.failed, 0);
    let hwm = |family: &str| {
        snap.depth_by_family
            .iter()
            .find(|(f, _)| f == family)
            .map(|(_, d)| *d)
            .unwrap_or(0)
    };
    let live = |family: &str| {
        snap.current_depth_by_family
            .iter()
            .find(|(f, _)| f == family)
            .map(|(_, d)| *d)
            .unwrap_or(0)
    };
    assert!(
        hwm("edge_cnn") >= 2,
        "the flood must have widened the family (else this test proves nothing), \
         high watermarks: {:?}",
        snap.depth_by_family
    );
    assert_eq!(
        live("edge_cnn"),
        1,
        "a drained family must release its width back to the lease without new \
         pushes, live gauges: {:?}",
        snap.current_depth_by_family
    );
    server.shutdown();
}
