//! Cross-module integration tests: zoo → characterize → schedule →
//! simulate → reports, plus config round-trips. These exercise the
//! same paths the figure benches use, with hard assertions on the
//! paper's qualitative claims.

use mensa::accel::configs;
use mensa::bench_harness;
use mensa::config::SystemSpec;
use mensa::model::zoo;
use mensa::scheduler::{Mapping, MensaScheduler};
use mensa::sim::Simulator;
use mensa::util::stats;

#[test]
fn full_pipeline_runs_for_every_zoo_model() {
    let mensa = configs::mensa_g();
    let scheduler = MensaScheduler::new(&mensa);
    let sim = Simulator::new(&mensa);
    for model in zoo::all() {
        let mapping = scheduler.schedule(&model);
        let r = sim.run(&model, &mapping);
        assert!(r.total_latency_s > 0.0, "{}", model.name);
        assert!(r.total_energy_j() > 0.0, "{}", model.name);
        assert!(r.avg_utilization() > 0.0 && r.avg_utilization() <= 1.0, "{}", model.name);
        assert_eq!(r.layer_execs.len(), model.len());
    }
}

#[test]
fn paper_headline_energy_and_throughput() {
    // The §7 headlines: Mensa-G ~66% energy reduction and ~3.1x
    // throughput vs the Edge TPU baseline (arithmetic means over the
    // 24 models, as the paper reports).
    let base_sys = configs::baseline_system();
    let mensa_sys = configs::mensa_g();
    let base_sim = Simulator::new(&base_sys);
    let mensa_sim = Simulator::new(&mensa_sys);
    let scheduler = MensaScheduler::new(&mensa_sys);
    let mut red = Vec::new();
    let mut tput = Vec::new();
    let mut lat = Vec::new();
    for model in zoo::all() {
        let b = base_sim.run(&model, &Mapping::uniform(model.len(), 0));
        let m = mensa_sim.run(&model, &scheduler.schedule(&model));
        red.push(1.0 - m.total_energy_j() / b.total_energy_j());
        tput.push(m.throughput_flops() / b.throughput_flops());
        lat.push(b.total_latency_s / m.total_latency_s);
    }
    let mean_red = stats::mean(&red);
    let mean_tput = stats::mean(&tput);
    let mean_lat = stats::mean(&lat);
    assert!((0.50..0.80).contains(&mean_red), "energy reduction {mean_red} (paper 0.66)");
    assert!((2.2..4.2).contains(&mean_tput), "throughput {mean_tput}x (paper 3.1x)");
    assert!((1.5..4.5).contains(&mean_lat), "latency gain {mean_lat}x (paper 1.96x)");
}

#[test]
fn sequence_models_benefit_most() {
    // Fig. 11/12: LSTMs and Transducers see the largest gains.
    let base_sys = configs::baseline_system();
    let mensa_sys = configs::mensa_g();
    let scheduler = MensaScheduler::new(&mensa_sys);
    let mut seq = Vec::new();
    let mut cnn = Vec::new();
    for model in zoo::all() {
        let b = Simulator::new(&base_sys).run(&model, &Mapping::uniform(model.len(), 0));
        let m = Simulator::new(&mensa_sys).run(&model, &scheduler.schedule(&model));
        let gain = b.total_latency_s / m.total_latency_s;
        if model.kind.is_sequence_class() {
            seq.push(gain);
        } else if matches!(model.kind, mensa::model::ModelKind::Cnn) {
            cnn.push(gain);
        }
    }
    assert!(stats::mean(&seq) > 3.0, "sequence gain {}", stats::mean(&seq));
    assert!(stats::mean(&seq) > 2.0 * stats::mean(&cnn), "LSTM gains must dominate CNN gains");
}

#[test]
fn mensa_switches_stay_low_like_paper() {
    // §5.6: typically 4-5 inter-accelerator communications; CNN5-7
    // (skip-heavy) communicate more.
    let sys = configs::mensa_g();
    let scheduler = MensaScheduler::new(&sys);
    let mut normal = Vec::new();
    let mut skip_heavy = Vec::new();
    for model in zoo::all() {
        let switches = scheduler.schedule(&model).switch_count() as f64;
        match model.name.as_str() {
            "CNN5" | "CNN6" | "CNN7" => skip_heavy.push(switches),
            _ => normal.push(switches),
        }
    }
    assert!(stats::mean(&normal) <= 8.0, "normal switches {}", stats::mean(&normal));
    assert!(stats::max(&normal) <= 16.0);
}

#[test]
fn shipped_configs_load_and_match_builtins() {
    let root = concat!(env!("CARGO_MANIFEST_DIR"), "/configs");
    for (file, builtin) in [
        ("baseline.toml", configs::baseline_system()),
        ("base_hb.toml", configs::base_hb_system()),
        ("eyeriss_v2.toml", configs::eyeriss_system()),
        ("mensa_g.toml", configs::mensa_g()),
    ] {
        let spec = SystemSpec::from_file(&format!("{root}/{file}")).expect(file);
        assert_eq!(spec.system.len(), builtin.len(), "{file}");
        for (a, b) in spec.system.accels.iter().zip(&builtin.accels) {
            assert_eq!(a.name, b.name, "{file}");
            assert_eq!(a.pe_rows, b.pe_rows, "{file}/{}", a.name);
            assert_eq!(a.pe_cols, b.pe_cols, "{file}/{}", a.name);
            assert_eq!(a.param_buf_bytes, b.param_buf_bytes, "{file}/{}", a.name);
            assert_eq!(a.act_buf_bytes, b.act_buf_bytes, "{file}/{}", a.name);
            assert_eq!(a.dataflow, b.dataflow, "{file}/{}", a.name);
            assert_eq!(a.memory, b.memory, "{file}/{}", a.name);
            assert!((a.clock_ghz - b.clock_ghz).abs() < 1e-9, "{file}/{}", a.name);
        }
    }
}

#[test]
fn config_driven_simulation_matches_builtin() {
    // A simulation driven by the shipped mensa_g.toml must reproduce
    // the built-in system's numbers exactly.
    let root = concat!(env!("CARGO_MANIFEST_DIR"), "/configs");
    let spec = SystemSpec::from_file(&format!("{root}/mensa_g.toml")).unwrap();
    let builtin = configs::mensa_g();
    let model = zoo::cnn(3);
    let m1 = MensaScheduler::new(&spec.system).schedule(&model);
    let m2 = MensaScheduler::new(&builtin).schedule(&model);
    assert_eq!(m1.as_slice(), m2.as_slice());
    let r1 = Simulator::new(&spec.system).run(&model, &m1);
    let r2 = Simulator::new(&builtin).run(&model, &m2);
    assert!((r1.total_energy_j() - r2.total_energy_j()).abs() < 1e-12);
    assert!((r1.total_latency_s - r2.total_latency_s).abs() < 1e-15);
}

#[test]
fn all_experiments_emit_reports() {
    for id in bench_harness::EXPERIMENTS {
        let report = bench_harness::run_experiment(id).unwrap();
        assert!(report.contains("paper:"), "{id} lacks a paper cross-reference");
    }
}

#[test]
fn base_hb_helps_lstms_most() {
    // Fig. 11: Base+HB's largest throughput wins are LSTM/Transducer
    // (~4.5x) vs CNNs (~1.3x).
    let base = configs::baseline_system();
    let hb = configs::base_hb_system();
    let mut seq = Vec::new();
    let mut cnn = Vec::new();
    for model in zoo::all() {
        let b = Simulator::new(&base).run(&model, &Mapping::uniform(model.len(), 0));
        let h = Simulator::new(&hb).run(&model, &Mapping::uniform(model.len(), 0));
        let gain = h.throughput_flops() / b.throughput_flops();
        if model.kind.is_sequence_class() {
            seq.push(gain);
        } else if matches!(model.kind, mensa::model::ModelKind::Cnn) {
            cnn.push(gain);
        }
    }
    let seq_gain = stats::mean(&seq);
    let cnn_gain = stats::mean(&cnn);
    assert!((3.0..8.0).contains(&seq_gain), "LSTM Base+HB gain {seq_gain} (paper 4.5x)");
    assert!(cnn_gain < 1.6, "CNN Base+HB gain {cnn_gain} (paper 1.3x)");
}
