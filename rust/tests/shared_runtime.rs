//! Shared-artifact acceptance: starting a server must parse the
//! manifest exactly once regardless of worker count (all executor
//! workers clone one `Arc<Runtime>`).
//!
//! This is a **single-test binary on purpose**: `manifest_load_count`
//! is a process-wide counter, and cargo runs tests within one binary
//! concurrently — any sibling test that loaded a runtime would race
//! the delta assertion. Keep it that way.

use mensa::config::ServerConfig;
use mensa::coordinator::Server;
use mensa::runtime::manifest_load_count;
use std::time::Duration;

fn artifacts_dir() -> Option<String> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(&format!("{dir}/manifest.toml")).exists() {
        Some(dir.to_string())
    } else {
        eprintln!("SKIP: no artifacts; run `make artifacts`");
        None
    }
}

#[test]
fn startup_parses_manifest_once_regardless_of_worker_count() {
    let Some(dir) = artifacts_dir() else { return };
    for workers in [1usize, 4, 8] {
        let before = manifest_load_count();
        let cfg = ServerConfig { workers, ..Default::default() };
        let server = Server::start(&dir, cfg).expect("start");
        let after = manifest_load_count();
        assert_eq!(
            after - before,
            1,
            "{workers}-worker startup must load the manifest exactly once"
        );
        // The shared runtime actually serves.
        let input: Vec<f32> = (0..32 * 32 * 3).map(|i| (i % 13) as f32 / 13.0).collect();
        let resp = server
            .infer_blocking("edge_cnn", vec![input], Duration::from_secs(30))
            .expect("inference on shared runtime");
        assert_eq!(resp.output.len(), 16);
        server.shutdown();
    }
}
