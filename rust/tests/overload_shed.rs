//! Overload-protection contracts, end to end through the server:
//!
//! * **admission control** — under `overload = "shed"`, a
//!   deadline-carrying request whose modeled queue + execution time
//!   already exceeds its budget is rejected *at `infer()`*, before it
//!   occupies any queue slot (`jobs_shed`); the same request under
//!   `overload = "block"` executes and surfaces as a `deadline_miss`;
//! * **enqueue shedding preserves FIFO** — chunks bounced by the
//!   non-blocking pool path error their requests immediately, but
//!   still fill their `(seq, chunk)` reorder slots: every response
//!   that *is* delivered stays bit-exact and in submission order
//!   (`fifo_violations == 0`), and nothing hangs at shutdown;
//! * **priority tiers shed lowest first** — a tier-3 family rides out
//!   a burst that sheds a tier-0 family, deterministically (the
//!   effective cap scales with `priority + 1`);
//! * **dequeue expiry** — a chunk whose member deadlines have *all*
//!   blown while queued is dropped without executing
//!   (`jobs_expired`); a mixed chunk (any live or deadline-free
//!   member) executes, and its late members count `deadline_misses`;
//! * **hierarchical escalation** — with `escalate_to` configured,
//!   low-confidence small-family outputs are re-served by the large
//!   family (bit-exact against solo large-family runs), while an
//!   exhausted deadline budget falls back to the small result;
//! * **roster composition** — the shed ladder works unchanged on a
//!   heterogeneous `[[device]]` pool.

use mensa::config::{DeviceClass, DeviceClassSpec, FamilyPolicy, OverloadPolicy, ServerConfig};
use mensa::coordinator::{device, Server};
use mensa::runtime::Precision;
use mensa::util::rng::Rng;
use std::fmt::Write as _;
use std::sync::OnceLock;
use std::time::Duration;

const TIMEOUT: Duration = Duration::from_secs(30);

fn artifacts_dir() -> Option<String> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(&format!("{dir}/manifest.toml")).exists() {
        Some(dir.to_string())
    } else {
        eprintln!("SKIP: no artifacts; run `make artifacts`");
        None
    }
}

fn cnn_input(rng: &mut Rng) -> Vec<f32> {
    (0..32 * 32 * 3).map(|_| rng.range_f64(0.0, 1.0) as f32).collect()
}

fn lstm_input(rng: &mut Rng) -> Vec<f32> {
    (0..8 * 128).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect()
}

fn policy(name: &str, priority: u8, escalate_to: Option<&str>) -> FamilyPolicy {
    FamilyPolicy {
        name: name.to_string(),
        priority,
        escalate_to: escalate_to.map(str::to_string),
        precision: Precision::F32,
    }
}

#[test]
fn admission_sheds_unmeetable_deadlines_before_queueing() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rng = Rng::new(0xADC1);
    let x = cnn_input(&mut rng);
    // 50 ms emulated device: the modeled per-chunk service time the
    // admission controller prices queue positions with.
    let base = ServerConfig {
        workers: 1,
        device_latency_us: 50_000,
        ..Default::default()
    };

    // Shed mode: a 10 ms budget cannot cover one 50 ms chunk even on
    // an idle server — rejected at infer(), zero device time burned.
    let cfg = ServerConfig { overload: OverloadPolicy::Shed, ..base.clone() };
    let server = Server::start(&dir, cfg).expect("start shed server");
    let err = server
        .infer_request("edge_cnn", vec![x.clone()])
        .deadline(Duration::from_millis(10))
        .send()
        .expect_err("10 ms budget against a 50 ms modeled chunk must shed");
    assert!(format!("{err:#}").contains("admission shed"), "{err:#}");
    // A roomy budget and a deadline-free request both pass admission.
    let ok = server
        .infer_request("edge_cnn", vec![x.clone()])
        .deadline(Duration::from_secs(5))
        .send()
        .expect("roomy budget admits");
    ok.recv_timeout(TIMEOUT).expect("recv").expect("roomy budget completes");
    server.infer_blocking("edge_cnn", vec![x.clone()], TIMEOUT).expect("no deadline, no shed");
    let snap = server.metrics();
    assert_eq!(snap.jobs_shed, 1, "exactly the unmeetable request shed");
    assert_eq!(snap.completed, 2);
    assert_eq!(snap.failed, 0, "shed work is overload protection, not failure");
    assert_eq!(snap.deadline_misses, 0, "the roomy budget was met");
    server.shutdown();

    // Block mode never admission-sheds: the same hopeless request
    // executes — and its lateness is visible as a deadline miss.
    let server = Server::start(&dir, base).expect("start block server");
    let rx = server
        .infer_request("edge_cnn", vec![x])
        .deadline(Duration::from_millis(10))
        .send()
        .expect("block mode admits everything");
    rx.recv_timeout(TIMEOUT).expect("recv").expect("block mode still serves it");
    let snap = server.metrics();
    assert_eq!(snap.jobs_shed, 0);
    assert_eq!(snap.completed, 1);
    assert_eq!(snap.deadline_misses, 1, "delivered past its 10 ms budget");
    server.shutdown();
}

#[test]
fn enqueue_shedding_keeps_delivered_responses_exact_and_in_order() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rng = Rng::new(0x5EED);
    let inputs: Vec<Vec<f32>> = (0..24).map(|_| cnn_input(&mut rng)).collect();
    // Solo reference outputs (batch-1, default server).
    let solo_server = Server::start(&dir, ServerConfig::default()).expect("solo");
    let solo: Vec<Vec<f32>> = inputs
        .iter()
        .map(|x| solo_server.infer_blocking("edge_cnn", vec![x.clone()], TIMEOUT).unwrap().output)
        .collect();
    solo_server.shutdown();

    // One chunk per request (max_batch 1), 50 ms device windows, and
    // the reorder path (depth 4 → effective cap 8): a 24-request burst
    // must overflow the bounded queue, and shed mode bounces the
    // overflow instead of parking the batcher.
    let cfg = ServerConfig {
        workers: 4,
        max_batch: 1,
        batch_timeout_us: 1_000,
        work_stealing: true,
        reorder_depth: 4,
        device_latency_us: 50_000,
        overload: OverloadPolicy::Shed,
        ..Default::default()
    };
    let server = Server::start(&dir, cfg).expect("start");
    let rxs: Vec<_> = inputs
        .iter()
        .map(|x| loop {
            // Retry router backpressure; pool-level shedding answers
            // through the reply channel, not here.
            match server.infer_request("edge_cnn", vec![x.clone()]).send() {
                Ok(rx) => break rx,
                Err(_) => std::thread::sleep(Duration::from_micros(200)),
            }
        })
        .collect();
    let mut shed = 0u64;
    let mut completed = 0u64;
    for (i, rx) in rxs.into_iter().enumerate() {
        match rx.recv_timeout(TIMEOUT).expect("every request gets a terminal reply") {
            Ok(resp) => {
                completed += 1;
                assert_eq!(resp.output, solo[i], "request {i}: delivered responses bit-exact");
            }
            Err(e) => {
                shed += 1;
                assert!(
                    format!("{e:#}").contains("shed"),
                    "request {i}: only shed errors expected, got {e:#}"
                );
            }
        }
    }
    assert!(shed >= 4, "a 24-burst against a cap of 8 must shed, shed only {shed}");
    assert!(completed >= 8, "the bounded queue's worth must still be served");
    let snap = server.metrics();
    assert_eq!(snap.jobs_shed, shed, "every client-visible shed is counted once");
    assert_eq!(snap.completed, completed);
    assert_eq!(snap.completed + snap.jobs_shed, 24, "conservation: served + shed = offered");
    assert_eq!(snap.failed, 0);
    assert_eq!(
        snap.fifo_violations, 0,
        "shed chunks must fill their reorder slots — order survives shedding"
    );
    // The log-bucketed latency histogram is populated and ordered.
    assert!(snap.p50_us > 0.0, "completions must land in the histogram");
    assert!(snap.p50_us <= snap.p95_us && snap.p95_us <= snap.p99_us);
    server.shutdown();
}

#[test]
fn priority_tiers_shed_the_low_tier_first() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rng = Rng::new(0x7137);
    let hi_inputs: Vec<Vec<f32>> = (0..6).map(|_| lstm_input(&mut rng)).collect();
    let lo_inputs: Vec<Vec<f32>> = (0..6).map(|_| cnn_input(&mut rng)).collect();
    // One worker, one chunk per request, lease discipline (cap 2):
    // tier 0 bounces past 2 queued chunks, tier 3 past 8. The worker
    // claims the tier-3 family first (priority-ordered claim) and sits
    // in 50 ms device windows, so the tier-0 burst meets a full queue.
    let cfg = ServerConfig {
        workers: 1,
        max_batch: 1,
        batch_timeout_us: 1_000,
        device_latency_us: 50_000,
        overload: OverloadPolicy::Shed,
        families: vec![policy("edge_lstm", 3, None), policy("edge_cnn", 0, None)],
        ..Default::default()
    };
    let server = Server::start(&dir, cfg).expect("start");
    let hi_rxs: Vec<_> = hi_inputs
        .iter()
        .map(|x| server.infer_request("edge_lstm", vec![x.clone()]).send().expect("submit hi"))
        .collect();
    let lo_rxs: Vec<_> = lo_inputs
        .iter()
        .map(|x| server.infer_request("edge_cnn", vec![x.clone()]).send().expect("submit lo"))
        .collect();
    let mut hi_shed = 0u64;
    for rx in hi_rxs {
        if rx.recv_timeout(TIMEOUT).expect("hi reply").is_err() {
            hi_shed += 1;
        }
    }
    let mut lo_shed = 0u64;
    for rx in lo_rxs {
        if rx.recv_timeout(TIMEOUT).expect("lo reply").is_err() {
            lo_shed += 1;
        }
    }
    assert_eq!(hi_shed, 0, "6 chunks sit under the tier-3 cap of 8 — nothing sheds");
    assert!(lo_shed >= 1, "the tier-0 burst exceeds its cap of 2 and must shed");
    let snap = server.metrics();
    assert_eq!(snap.jobs_shed, lo_shed, "all shedding landed on the low tier");
    assert_eq!(snap.completed + snap.jobs_shed, 12);
    assert_eq!(snap.fifo_violations, 0);
    assert_eq!(snap.failed, 0);
    server.shutdown();
}

#[test]
fn expired_chunks_drop_at_dequeue_and_mixed_chunks_execute() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rng = Rng::new(0xE817);
    // One worker, pairs per chunk, 50 ms device windows, shed mode.
    let cfg = ServerConfig {
        workers: 1,
        max_batch: 2,
        batch_timeout_us: 20_000,
        device_latency_us: 50_000,
        overload: OverloadPolicy::Shed,
        ..Default::default()
    };
    let server = Server::start(&dir, cfg).expect("start");

    // Phase 1 — a MIXED chunk must execute. Six deadline-free cnn
    // blockers (three 50 ms chunks) occupy the worker; then one
    // deadline-free + one 60 ms-deadline lstm request coalesce into a
    // single chunk. Its deadline member blows while queued, but the
    // deadline-free member keeps the chunk alive: both are served, and
    // the late one counts a deadline miss — not an expiry.
    let blockers: Vec<_> = (0..6)
        .map(|_| {
            let x = cnn_input(&mut rng);
            server.infer_request("edge_cnn", vec![x]).send().expect("blocker")
        })
        .collect();
    std::thread::sleep(Duration::from_millis(10));
    let free = server
        .infer_request("edge_lstm", vec![lstm_input(&mut rng)])
        .send()
        .expect("free member");
    let dead = server
        .infer_request("edge_lstm", vec![lstm_input(&mut rng)])
        .deadline(Duration::from_millis(60))
        .send()
        .expect("60 ms budget passes admission on an empty lstm queue");
    for rx in blockers {
        rx.recv_timeout(TIMEOUT).expect("recv").expect("blocker completes");
    }
    free.recv_timeout(TIMEOUT).expect("recv").expect("deadline-free member served");
    dead.recv_timeout(TIMEOUT).expect("recv").expect("mixed chunk executes its late member");
    let snap = server.metrics();
    assert_eq!(snap.jobs_expired, 0, "a mixed chunk never expires");
    assert_eq!(snap.deadline_misses, 1, "the late member is a miss, not an expiry");

    // Phase 2 — an ALL-EXPIRED chunk must drop at dequeue. Four fresh
    // blockers (two 50 ms chunks) delay the worker ~100 ms; two lstm
    // requests that BOTH carry 60 ms budgets pass admission (their own
    // queue is empty — cross-family wait is the model's blind spot)
    // and then blow their deadlines while queued: the whole chunk is
    // refused before execution.
    let blockers: Vec<_> = (0..4)
        .map(|_| {
            let x = cnn_input(&mut rng);
            server.infer_request("edge_cnn", vec![x]).send().expect("blocker")
        })
        .collect();
    std::thread::sleep(Duration::from_millis(10));
    let doomed: Vec<_> = (0..2)
        .map(|_| {
            server
                .infer_request("edge_lstm", vec![lstm_input(&mut rng)])
                .deadline(Duration::from_millis(60))
                .send()
                .expect("passes admission: the lstm queue itself is empty")
        })
        .collect();
    for rx in blockers {
        rx.recv_timeout(TIMEOUT).expect("recv").expect("blocker completes");
    }
    for (i, rx) in doomed.into_iter().enumerate() {
        let err = rx
            .recv_timeout(TIMEOUT)
            .expect("expired requests still get a terminal reply")
            .expect_err("an all-expired chunk must not deliver outputs");
        assert!(
            format!("{err:#}").contains("deadline expired"),
            "request {i}: expected the expiry error, got {err:#}"
        );
    }
    let snap = server.metrics();
    assert_eq!(snap.jobs_expired, 2, "both members of the all-expired chunk counted");
    assert_eq!(snap.deadline_misses, 1, "no new misses: expired work is never delivered");
    assert_eq!(snap.failed, 0, "expiry is overload protection, not failure");
    assert_eq!(snap.completed, 12, "every deadline-free request was served");
    assert_eq!(snap.fifo_violations, 0, "dropped chunks still advance the cursor");
    server.shutdown();
}

/// Write a synthetic two-family manifest (shared input shape, so a
/// request can be re-served verbatim by the large family) once per
/// process: `tiny` (12 → 6) escalates to `big` (12 → 20).
fn escalation_manifest_dir() -> &'static str {
    static DIR: OnceLock<String> = OnceLock::new();
    DIR.get_or_init(|| {
        let dir = std::env::temp_dir().join(format!("mensa_overload_shed_{}", std::process::id()));
        std::fs::create_dir_all(&dir).expect("create manifest dir");
        let mut m = String::from("# Generated by overload_shed.rs — escalation pair.\n");
        for (fam, d_out) in [("tiny", 6usize), ("big", 20usize)] {
            for b in [1usize, 4] {
                let _ = write!(
                    m,
                    "\n[[artifact]]\nname = \"{fam}_b{b}\"\nfile = \"{fam}_b{b}.hlo.txt\"\n\
                     num_inputs = 1\ninput0_shape = \"{b}x12\"\ninput0_batch_axis = 0\n\
                     output_shape = \"{b}x{d_out}\"\noutput_batch_axis = 0\n\
                     sha256 = \"referencebackend\"\n"
                );
            }
        }
        std::fs::write(dir.join("manifest.toml"), m).expect("write manifest");
        dir.to_str().expect("utf8 temp dir").to_string()
    })
}

#[test]
fn escalation_reserves_low_confidence_requests_on_the_large_family() {
    let dir = escalation_manifest_dir();
    let inputs: Vec<Vec<f32>> = (0..8)
        .map(|r| (0..12).map(|i| (((i * 31 + r * 7 + 3) % 101) as f32 / 101.0) - 0.5).collect())
        .collect();
    // Solo references for both families (no escalation configured).
    let solo_server = Server::start(dir, ServerConfig::default()).expect("solo");
    let solo_tiny: Vec<Vec<f32>> = inputs
        .iter()
        .map(|x| solo_server.infer_blocking("tiny", vec![x.clone()], TIMEOUT).unwrap().output)
        .collect();
    let solo_big: Vec<Vec<f32>> = inputs
        .iter()
        .map(|x| solo_server.infer_blocking("big", vec![x.clone()], TIMEOUT).unwrap().output)
        .collect();
    solo_server.shutdown();

    // Threshold 1.0: every dense output scores below it, so every
    // `tiny` request escalates — responses must be `big`'s outputs,
    // bit-exact, delivered on the original reply channels.
    let cfg = ServerConfig {
        families: vec![policy("tiny", 0, Some("big"))],
        escalation_threshold: 1.0,
        ..Default::default()
    };
    let server = Server::start(dir, cfg).expect("start");
    let rxs: Vec<_> = inputs
        .iter()
        .map(|x| server.infer_request("tiny", vec![x.clone()]).send().expect("submit"))
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv_timeout(TIMEOUT).expect("recv").expect("ok");
        assert_eq!(resp.output.len(), 20, "request {i}: served by the large family");
        assert_eq!(resp.output, solo_big[i], "request {i}: bit-exact against solo big");
    }
    let mid = server.metrics();
    assert_eq!(mid.escalations, 8, "every request took the cascade");
    assert_eq!(mid.completed, 8, "completion recorded once, at final delivery");
    assert_eq!(mid.failed, 0);
    assert_eq!(mid.fifo_violations, 0);

    // An exhausted budget must NOT escalate: a better answer that is
    // guaranteed late loses to the small result now. (Block mode, so
    // the hopeless deadline is neither admission-shed nor expired.)
    let rx = server
        .infer_request("tiny", vec![inputs[0].clone()])
        .deadline(Duration::from_nanos(1))
        .send()
        .expect("submit");
    let resp = rx.recv_timeout(TIMEOUT).expect("recv").expect("small fallback delivers");
    assert_eq!(resp.output, solo_tiny[0], "budget-exhausted request keeps the small result");
    let snap = server.metrics();
    assert_eq!(snap.escalations, 8, "no escalation on an exhausted budget");
    assert_eq!(snap.deadline_misses, 1, "the late small result is still a miss");
    server.shutdown();

    // Threshold 0.0 is the off switch: nothing escalates.
    let cfg = ServerConfig {
        families: vec![policy("tiny", 0, Some("big"))],
        escalation_threshold: 0.0,
        ..Default::default()
    };
    let server = Server::start(dir, cfg).expect("start");
    let resp = server
        .infer_blocking("tiny", vec![inputs[0].clone()], TIMEOUT)
        .expect("ok");
    assert_eq!(resp.output, solo_tiny[0], "threshold 0 serves the small family");
    assert_eq!(server.metrics().escalations, 0);
    server.shutdown();
}

#[test]
fn escalation_target_must_be_loaded() {
    let dir = escalation_manifest_dir();
    let cfg = ServerConfig {
        families: vec![policy("tiny", 0, Some("missing"))],
        ..Default::default()
    };
    let err = Server::start(dir, cfg).expect_err("unloaded escalation target must be rejected");
    assert!(format!("{err:#}").contains("missing"), "{err:#}");
    let cfg = ServerConfig {
        families: vec![policy("ghost", 1, None)],
        ..Default::default()
    };
    let err = Server::start(dir, cfg).expect_err("[[family]] must name a loaded family");
    assert!(format!("{err:#}").contains("ghost"), "{err:#}");
}

#[test]
fn shed_ladder_composes_with_a_device_roster() {
    let Some(dir) = artifacts_dir() else { return };
    let families: Vec<String> =
        vec!["edge_cnn".into(), "edge_lstm".into(), "joint".into()];
    // Calibrate the roster so its slowest modeled (class, family)
    // window is ~20 ms — test-friendly absolute scale, heterogeneity
    // (and with it the placement) preserved.
    let probe = vec![
        DeviceClassSpec { class: DeviceClass::Pascal, workers: 1, latency_scale: 1.0 },
        DeviceClassSpec { class: DeviceClass::Pavlov, workers: 1, latency_scale: 1.0 },
    ];
    let profiles = device::build_profiles(&probe, &families, Duration::ZERO);
    let max_base = profiles
        .iter()
        .flat_map(|p| families.iter().map(move |f| p.base_latency_s(f)))
        .fold(0.0f64, f64::max);
    let scale = Duration::from_millis(20).as_secs_f64() / max_base.max(1e-12);
    let roster: Vec<DeviceClassSpec> = probe
        .into_iter()
        .map(|mut spec| {
            spec.latency_scale = scale;
            spec
        })
        .collect();

    let cfg = ServerConfig {
        max_batch: 1,
        batch_timeout_us: 1_000,
        work_stealing: true,
        devices: roster,
        overload: OverloadPolicy::Shed,
        ..Default::default()
    };
    let server = Server::start(&dir, cfg).expect("start");
    // Admission control prices chunks with the *placed* class's
    // modeled window — microseconds of budget cannot buy one.
    let mut rng = Rng::new(0x0575);
    let err = server
        .infer_request("edge_cnn", vec![cnn_input(&mut rng)])
        .deadline(Duration::from_micros(1))
        .send()
        .expect_err("1 µs budget must shed at admission under a roster");
    assert!(format!("{err:#}").contains("admission shed"), "{err:#}");
    // A deadline-free burst sheds at enqueue past the bounded queue —
    // never fails, never hangs, FIFO intact.
    let rxs: Vec<_> = (0..16)
        .map(|_| {
            let x = cnn_input(&mut rng);
            server.infer_request("edge_cnn", vec![x]).send().expect("submit")
        })
        .collect();
    let mut served = 0u64;
    for rx in rxs {
        if rx.recv_timeout(TIMEOUT).expect("terminal reply").is_ok() {
            served += 1;
        }
    }
    let snap = server.metrics();
    assert_eq!(snap.completed, served);
    assert_eq!(snap.completed + snap.jobs_shed, 16 + 1, "conservation incl. the admission shed");
    assert_eq!(snap.failed, 0);
    assert_eq!(snap.fifo_violations, 0);
    server.shutdown();
}
