//! The `MENSA_KERNEL` dispatch-override hook, isolated in its own
//! test binary: the tests below mutate the process environment, and
//! cargo runs each integration-test binary as its own process (tests
//! *within* a binary run concurrently, so this file holds exactly one
//! `#[test]`), which keeps the mutation from racing the kernel-path
//! suites.
//!
//! This is the hook CI's forced-fallback matrix leg uses
//! (`MENSA_KERNEL=scalar` on an AVX2 runner), so it must demonstrably
//! override the configured kernel — including an explicit
//! `kernel = "simd"` — and reject junk values at load.

use mensa::runtime::{
    simd_kernel_available, KernelKind, Runtime, RuntimeOptions, KERNEL_ENV,
};
use std::fmt::Write as _;

fn manifest_dir() -> String {
    let dir = std::env::temp_dir().join(format!("mensa_kernel_env_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create manifest dir");
    let mut m = String::new();
    let _ = write!(
        m,
        "[[artifact]]\nname = \"envfam_b4\"\nfile = \"envfam_b4.hlo.txt\"\n\
         num_inputs = 1\ninput0_shape = \"4x16\"\ninput0_batch_axis = 0\n\
         output_shape = \"4x16\"\noutput_batch_axis = 0\nsha256 = \"referencebackend\"\n"
    );
    std::fs::write(dir.join("manifest.toml"), m).expect("write manifest");
    dir.to_str().expect("utf8 temp dir").to_string()
}

#[test]
fn env_override_wins_over_config_and_rejects_junk() {
    let dir = manifest_dir();
    // Force scalar over the default (auto) config.
    std::env::set_var(KERNEL_ENV, "scalar");
    let rt = Runtime::load(&dir).expect("load under scalar override");
    assert_eq!(rt.kernel_path(), "scalar", "override must force the portable path");
    // The override also beats an explicit `kernel = "simd"` — that is
    // the whole point of the CI hook (run everything scalar without
    // touching configs). Only meaningful where simd could resolve.
    if simd_kernel_available() {
        let rt = Runtime::load_with(
            &dir,
            RuntimeOptions { kernel: KernelKind::Simd, ..Default::default() },
        )
        .expect("load simd-config under scalar override");
        assert_eq!(rt.kernel_path(), "scalar", "override beats explicit simd");
    }
    // Junk values fail the load loudly instead of silently defaulting.
    std::env::set_var(KERNEL_ENV, "avx512");
    let err = Runtime::load(&dir).expect_err("junk override must fail");
    assert!(format!("{err:#}").contains("unknown kernel"), "{err:#}");
    // Empty means unset (how CI's `auto` matrix leg spells "no
    // override").
    std::env::set_var(KERNEL_ENV, "");
    let rt = Runtime::load(&dir).expect("empty override is ignored");
    let expect = if simd_kernel_available() { "simd" } else { "scalar" };
    assert_eq!(rt.kernel_path(), expect, "empty override falls back to the config");
    std::env::remove_var(KERNEL_ENV);
}
