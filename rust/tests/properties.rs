//! Property-based tests over the coordinator/simulator invariants,
//! using the in-repo `util::check` helper (offline `proptest` stand-in;
//! every failure prints a replayable per-case seed).

use mensa::accel::configs;
use mensa::accel::dataflow::DataflowKind;
use mensa::characterize::{classify, LayerMetrics};
use mensa::coordinator::server::{pack_batch, unpack_batch};
use mensa::model::layer::{Gate, Layer, LayerKind};
use mensa::model::zoo;
use mensa::scheduler::{Mapping, MensaScheduler};
use mensa::sim::Simulator;
use mensa::util::check::{ensure, for_all};
use mensa::util::rng::Rng;

/// Generate a random (but structurally valid) layer.
fn gen_layer(rng: &mut Rng) -> Layer {
    let kind = match rng.range_u64(0, 6) {
        0 => LayerKind::Conv2d {
            in_h: rng.range_u64(7, 112) as u32,
            in_w: rng.range_u64(7, 112) as u32,
            in_c: rng.range_u64(3, 256) as u32,
            out_c: rng.range_u64(8, 256) as u32,
            k: *rng.pick(&[1u32, 3, 5]),
            stride: *rng.pick(&[1u32, 2]),
        },
        1 => LayerKind::Depthwise {
            in_h: rng.range_u64(7, 56) as u32,
            in_w: rng.range_u64(7, 56) as u32,
            channels: rng.range_u64(8, 512) as u32,
            k: *rng.pick(&[3u32, 5]),
            stride: *rng.pick(&[1u32, 2]),
        },
        2 => LayerKind::Pointwise {
            in_h: rng.range_u64(7, 56) as u32,
            in_w: rng.range_u64(7, 56) as u32,
            in_c: rng.range_u64(8, 512) as u32,
            out_c: rng.range_u64(8, 512) as u32,
        },
        3 => LayerKind::FullyConnected {
            in_dim: rng.range_u64(16, 4096) as u32,
            out_dim: rng.range_u64(16, 4096) as u32,
        },
        4 => LayerKind::LstmGate {
            input_dim: rng.range_u64(64, 2048) as u32,
            hidden_dim: rng.range_u64(64, 2048) as u32,
            timesteps: rng.range_u64(1, 64) as u32,
            gate: *rng.pick(&Gate::ALL),
        },
        5 => LayerKind::LstmUpdate {
            hidden_dim: rng.range_u64(64, 2048) as u32,
            timesteps: rng.range_u64(1, 64) as u32,
        },
        _ => LayerKind::Pool {
            in_h: rng.range_u64(4, 56) as u32,
            in_w: rng.range_u64(4, 56) as u32,
            channels: rng.range_u64(8, 512) as u32,
            k: 2,
        },
    };
    Layer::new("prop", kind)
}

const ALL_DATAFLOWS: [DataflowKind; 5] = [
    DataflowKind::MonolithicWs,
    DataflowKind::EyerissRs,
    DataflowKind::PascalOs,
    DataflowKind::PavlovWs,
    DataflowKind::JacquardWs,
];

fn all_accels() -> Vec<mensa::accel::AccelConfig> {
    vec![
        configs::edge_tpu_baseline(),
        configs::base_hb(),
        configs::eyeriss_v2(),
        configs::pascal(),
        configs::pavlov(),
        configs::jacquard(),
    ]
}

#[test]
fn prop_utilization_bounded_on_every_dataflow() {
    let accels = all_accels();
    for_all(0xA1, 300, gen_layer, |layer| {
        for cfg in &accels {
            let c = cfg.dataflow.cost(cfg, layer);
            ensure(
                c.utilization.is_finite() && c.utilization >= 0.0 && c.utilization <= 1.0 + 1e-9,
                format!("{}: util {}", cfg.name, c.utilization),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_latency_and_traffic_nonnegative_and_finite() {
    let accels = all_accels();
    for_all(0xA2, 300, gen_layer, |layer| {
        for cfg in &accels {
            let c = cfg.dataflow.cost(cfg, layer);
            for (name, v) in [
                ("latency_s", c.latency_s),
                ("compute_cycles", c.compute_cycles),
                ("mem_cycles", c.mem_cycles),
                ("dram_param", c.dram_param_bytes),
                ("dram_act", c.dram_act_bytes),
                ("noc", c.noc_bytes),
                ("energy", c.energy.total_j()),
            ] {
                ensure(v.is_finite() && v >= 0.0, format!("{}: {name} = {v}", cfg.name))?;
            }
            ensure(c.latency_s > 0.0, format!("{}: zero latency", cfg.name))?;
        }
        Ok(())
    });
}

#[test]
fn prop_dram_param_traffic_at_least_one_fetch() {
    // No dataflow can fetch fewer bytes than the parameter footprint.
    let accels = all_accels();
    for_all(0xA3, 300, gen_layer, |layer| {
        let params = layer.param_bytes() as f64;
        for cfg in &accels {
            let c = cfg.dataflow.cost(cfg, layer);
            ensure(
                c.dram_param_bytes >= params - 1.0,
                format!("{}: dram {} < params {params}", cfg.name, c.dram_param_bytes),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_more_bandwidth_never_hurts_latency() {
    // Monotonicity: the same accelerator with more DRAM bandwidth must
    // not get slower on any layer.
    for_all(0xA4, 200, gen_layer, |layer| {
        let slow = configs::edge_tpu_baseline();
        let fast = configs::base_hb();
        let c_slow = slow.dataflow.cost(&slow, layer);
        let c_fast = fast.dataflow.cost(&fast, layer);
        ensure(
            c_fast.latency_s <= c_slow.latency_s * 1.0001,
            format!("{} vs {}", c_fast.latency_s, c_slow.latency_s),
        )
    });
}

#[test]
fn prop_classification_is_stable_and_total() {
    // classify() returns the same family on repeated calls and some
    // family for every layer (Outlier included).
    for_all(0xA5, 300, gen_layer, |layer| {
        let m = LayerMetrics::of(layer);
        let a = classify(&m);
        let b = classify(&m);
        ensure(a == b, "classification must be deterministic")
    });
}

#[test]
fn prop_scheduler_mappings_complete_and_in_range() {
    let sys = configs::mensa_g();
    let scheduler = MensaScheduler::new(&sys);
    for_all(
        0xA6,
        40,
        |rng| zoo::all().remove(rng.range_usize(0, 23)),
        |model| {
            let mapping = scheduler.schedule(model);
            ensure(mapping.len() == model.len(), "mapping covers all layers")?;
            ensure(
                mapping.as_slice().iter().all(|&a| a < sys.len()),
                "accelerator ids in range",
            )
        },
    );
}

#[test]
fn prop_simulator_energy_additive_over_layers() {
    // Total dynamic energy equals the sum of per-layer dynamic
    // energies plus transfer energy (conservation).
    let sys = configs::mensa_g();
    let sim = Simulator::new(&sys);
    let scheduler = MensaScheduler::new(&sys);
    for_all(
        0xA7,
        24,
        |rng| zoo::all().remove(rng.range_usize(0, 23)),
        |model| {
            let mapping = scheduler.schedule(model);
            let r = sim.run(model, &mapping);
            let per_layer: f64 = r.layer_execs.iter().map(|e| e.cost.energy.dynamic_j()).sum();
            let total_dyn = r.energy.dynamic_j();
            ensure(
                total_dyn >= per_layer - 1e-12,
                format!("dynamic {total_dyn} < sum {per_layer}"),
            )?;
            // The excess is exactly the transfer energy; bounded.
            ensure(
                (total_dyn - per_layer) <= r.transfer_bytes * 1e-9 + 1e-9,
                "transfer energy bounded by traffic",
            )
        },
    );
}

#[test]
fn prop_pack_unpack_roundtrip() {
    for_all(
        0xA8,
        200,
        |rng| {
            let inner = rng.range_usize(1, 64);
            let outer = rng.range_usize(1, 8);
            let n_req = rng.range_usize(1, 6);
            let batch = n_req + rng.range_usize(0, 4);
            let axis = rng.range_usize(0, 1);
            let reqs: Vec<Vec<f32>> = (0..n_req)
                .map(|_| (0..outer * inner).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect())
                .collect();
            (outer, inner, batch, axis, reqs)
        },
        |(outer, inner, batch, axis, reqs)| {
            // Shape with the batch inserted at `axis` of [outer, inner].
            let shape: Vec<i64> = if *axis == 0 {
                vec![*batch as i64, *outer as i64 * *inner as i64]
            } else {
                vec![*outer as i64, *batch as i64, *inner as i64]
            };
            let refs: Vec<&[f32]> = reqs.iter().map(|v| v.as_slice()).collect();
            let axis = if *axis == 0 { 0 } else { 1 };
            let packed = pack_batch(&shape, axis, &refs);
            ensure(
                packed.len() as i64 == shape.iter().product::<i64>(),
                "packed size matches shape",
            )?;
            // Unpacking mirrors packing on the same axis — including
            // the time-major axis-1 layout edge_lstm uses.
            let rows = unpack_batch(&packed, &shape, axis, reqs.len());
            for (i, row) in rows.iter().enumerate() {
                ensure(row == &reqs[i], format!("axis {axis}: row {i} corrupted"))?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_mapping_histogram_sums_to_len() {
    for_all(
        0xA9,
        200,
        |rng| {
            let n = rng.range_usize(1, 200);
            let k = rng.range_usize(1, 5);
            let v: Vec<usize> = (0..n).map(|_| rng.range_usize(0, k - 1)).collect();
            (v, k)
        },
        |(v, k)| {
            let m = Mapping::new(v.clone());
            let hist = m.histogram(*k);
            ensure(hist.iter().sum::<usize>() == v.len(), "histogram total")?;
            ensure(m.switch_count() < v.len().max(1), "switches < layers")
        },
    );
}

#[test]
fn prop_dataflow_ordering_for_family3() {
    // For any real LSTM gate, Pavlov must move fewer DRAM parameter
    // bytes than the monolithic baseline (the §5.4 invariant).
    for_all(
        0xAA,
        200,
        |rng| {
            Layer::new(
                "g",
                LayerKind::LstmGate {
                    input_dim: rng.range_u64(256, 2048) as u32,
                    hidden_dim: rng.range_u64(512, 2048) as u32,
                    timesteps: rng.range_u64(2, 64) as u32,
                    gate: *rng.pick(&Gate::ALL),
                },
            )
        },
        |layer| {
            let base = configs::edge_tpu_baseline();
            let pavlov = configs::pavlov();
            let cb = base.dataflow.cost(&base, layer);
            let cp = pavlov.dataflow.cost(&pavlov, layer);
            ensure(
                cp.dram_param_bytes <= cb.dram_param_bytes,
                format!("pavlov {} > baseline {}", cp.dram_param_bytes, cb.dram_param_bytes),
            )?;
            ensure(
                cp.energy.dram_dynamic_j < cb.energy.dram_dynamic_j,
                "pavlov DRAM energy must be lower",
            )
        },
    );
}

#[test]
fn prop_all_dataflows_enumerated() {
    // Guard: if a new dataflow is added, the property generators above
    // must be extended.
    assert_eq!(ALL_DATAFLOWS.len(), 5);
}
