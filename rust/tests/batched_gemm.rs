//! Batched-GEMM and reorder-buffer contracts.
//!
//! * **bit-identity** — the batched GEMM execution path must equal the
//!   per-sample path *bit for bit* on every real artifact variant
//!   (batch sizes 1/4/8, batch-major and time-major axes, one- and
//!   two-input families), including partial batches. Both paths use
//!   the same per-element accumulation order by construction; this is
//!   the property that lets the server flip `batched_gemm` without
//!   changing a single response.
//! * **reorder FIFO** — completions injected out of sequence order
//!   must be delivered in sequence order, and an end-to-end hot-family
//!   flood with `reorder_depth >= 2` must spread one family across
//!   several workers (intra-family parallelism) while clients still
//!   observe strict FIFO (`fifo_violations == 0`, responses bit-exact
//!   against solo runs).

use mensa::config::ServerConfig;
use mensa::coordinator::{ReorderBuffer, Server};
use mensa::runtime::{ExecScratch, Runtime, RuntimeOptions};
use mensa::util::check::{ensure, for_all};
use mensa::util::rng::Rng;
use std::time::Duration;

const TIMEOUT: Duration = Duration::from_secs(30);

fn artifacts_dir() -> Option<String> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(&format!("{dir}/manifest.toml")).exists() {
        Some(dir.to_string())
    } else {
        eprintln!("SKIP: no artifacts; run `make artifacts`");
        None
    }
}

/// Every variant of the real manifest: (name, capacity).
const VARIANTS: [(&str, usize); 7] = [
    ("edge_cnn_b1", 1),
    ("edge_cnn_b4", 4),
    ("edge_cnn_b8", 8),
    ("edge_lstm_b1", 1),
    ("edge_lstm_b4", 4),
    ("joint_b1", 1),
    ("joint_b4", 4),
];

#[test]
fn batched_gemm_is_bit_identical_to_per_sample_on_every_variant() {
    let Some(dir) = artifacts_dir() else { return };
    let batched = Runtime::load_with(&dir, RuntimeOptions::default()).expect("batched runtime");
    let per_sample = Runtime::load_with(
        &dir,
        RuntimeOptions { batched_gemm: false, ..Default::default() },
    )
    .expect("per-sample runtime");
    for (name, capacity) in VARIANTS {
        let mb = batched.model(name).expect("variant");
        let mp = per_sample.model(name).expect("variant");
        let sizes: Vec<usize> = mb
            .spec
            .input_shapes
            .iter()
            .map(|s| s.iter().product::<i64>() as usize)
            .collect();
        // Random full-batch inputs plus every partial-batch `active`
        // count, replayable per case.
        for_all(
            0xB17 ^ capacity as u64,
            16,
            |rng| {
                let inputs: Vec<Vec<f32>> = sizes
                    .iter()
                    .map(|&n| (0..n).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect())
                    .collect();
                let active = rng.range_usize(0, capacity);
                (inputs, active)
            },
            |(inputs, active)| {
                // Both batch-shaped entry points: the Runtime-level
                // one and the model-level one.
                let a = batched
                    .execute_batch(name, inputs, *active, &mut ExecScratch::default())
                    .map_err(|e| format!("{name}: batched exec failed: {e:#}"))?;
                let b = mp
                    .execute_with(inputs, *active, &mut ExecScratch::default())
                    .map_err(|e| format!("{name}: per-sample exec failed: {e:#}"))?;
                ensure(
                    a == b,
                    format!("{name}: batched != per-sample at active={active}"),
                )
            },
        );
    }
}

#[test]
fn reorder_buffer_delivers_shuffled_completions_in_sequence_order() {
    // Out-of-order completion injection: an adversarial submission
    // order (within the depth window anything can finish first) must
    // still deliver 0, 1, 2, … — the client-observed FIFO contract.
    let buf = ReorderBuffer::new();
    let order = [3u64, 0, 2, 1, 7, 4, 6, 5, 8, 11, 10, 9];
    let mut delivered: Vec<u64> = Vec::new();
    for seq in order {
        buf.submit("hot", seq, 0, true, seq, |v| delivered.push(v));
    }
    assert_eq!(delivered, (0..12).collect::<Vec<_>>(), "delivery must be in sequence order");
    assert_eq!(buf.pending(), 0, "nothing left buffered");
}

#[test]
fn reorder_buffer_delivers_shuffled_chunks_in_lexicographic_order() {
    // Chunk-granular injection: jobs 0..3 of 3/1/2 chunks, submitted
    // in an adversarial order, must deliver in (seq, chunk) order with
    // the `last` flag advancing the cursor across job boundaries.
    let buf = ReorderBuffer::new();
    let chunks = [
        (1u64, 0u32, true),
        (0, 2, true),
        (2, 1, true),
        (0, 0, false),
        (2, 0, false),
        (0, 1, false),
    ];
    let mut delivered: Vec<(u64, u32)> = Vec::new();
    for (seq, chunk, last) in chunks {
        buf.submit("hot", seq, chunk, last, (seq, chunk), |v| delivered.push(v));
    }
    assert_eq!(
        delivered,
        vec![(0, 0), (0, 1), (0, 2), (1, 0), (2, 0), (2, 1)],
        "delivery must be lexicographic in (flush seq, chunk seq)"
    );
    assert_eq!(buf.pending(), 0, "nothing left buffered");
}

fn cnn_input(rng: &mut Rng) -> Vec<f32> {
    (0..32 * 32 * 3).map(|_| rng.range_f64(0.0, 1.0) as f32).collect()
}

fn lstm_input(rng: &mut Rng) -> Vec<f32> {
    (0..8 * 128).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect()
}

#[test]
fn reorder_mode_spreads_a_hot_family_and_keeps_client_fifo() {
    let Some(dir) = artifacts_dir() else { return };
    // Per-job emulated device busy time is the overlap discriminator:
    // 8 requests at max_batch 2 are >= 4 jobs x 40 ms of device sleep,
    // so ANY one-worker-at-a-time discipline (the lease, or a broken
    // multi-holder fan-out) needs >= 160 ms wall just sleeping, while
    // genuine intra-family parallelism on 4 workers finishes the
    // sleeps in ~2 rounds (~80 ms). The wall-clock bound below (3
    // rounds — a full extra round of scheduling slack; sleeping
    // threads don't compete for cores, the compute is microseconds)
    // can only be met if same-family jobs truly overlap — unlike a
    // worker-set check, which lease-mode idle rotation also satisfies.
    const DEVICE: Duration = Duration::from_millis(40);
    let cfg = ServerConfig {
        workers: 4,
        max_batch: 2,
        batch_timeout_us: 1_000,
        work_stealing: true,
        reorder_depth: 4,
        device_latency_us: DEVICE.as_micros() as u64,
        ..Default::default()
    };
    let server = Server::start(&dir, cfg).expect("start");
    let mut rng = Rng::new(0xF1F0);
    let inputs: Vec<Vec<f32>> = (0..8).map(|_| cnn_input(&mut rng)).collect();
    // Solo baselines (sequential; also flow through the reorder path).
    let solo: Vec<Vec<f32>> = inputs
        .iter()
        .map(|x| server.infer_blocking("edge_cnn", vec![x.clone()], TIMEOUT).unwrap().output)
        .collect();
    // Hot-family flood: several small jobs queued at once, so several
    // workers must drain the one family concurrently.
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = inputs
        .iter()
        .map(|x| {
            // Retry backpressure (queue depth is finite under a flood).
            loop {
                match server.infer_request("edge_cnn", vec![x.clone()]).send() {
                    Ok(rx) => return rx,
                    Err(_) => std::thread::sleep(Duration::from_micros(200)),
                }
            }
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv_timeout(TIMEOUT).expect("recv").expect("ok");
        assert_eq!(
            resp.output, solo[i],
            "request {i}: reorder mode must stay bit-exact and in order"
        );
    }
    let flood_wall = t0.elapsed();
    // Serial lower bound is 4 jobs x 40 ms = 160 ms of pure sleep;
    // overlap needs ~2 rounds (~80 ms). Allow a third round of slack.
    assert!(
        flood_wall < DEVICE * 3,
        "hot-family flood took {flood_wall:?} — same-family jobs did not overlap \
         (serial device floor is {:?})",
        DEVICE * 4
    );
    let snap = server.metrics();
    assert_eq!(snap.fifo_violations, 0, "clients must observe strict FIFO");
    assert_eq!(snap.failed, 0);
    let workers_seen = snap
        .workers_by_family
        .iter()
        .find(|(f, _)| f == "edge_cnn")
        .map(|(_, ws)| ws.clone())
        .unwrap_or_default();
    assert!(
        workers_seen.len() >= 2,
        "one hot family must execute on several workers under reorder_depth=4, \
         saw {workers_seen:?}"
    );
    server.shutdown();
}

#[test]
fn reorder_mode_chunks_oversized_jobs_in_order() {
    // edge_lstm tops out at b4; oversized floods must chunk front to
    // back inside each job even when several workers run the family.
    let Some(dir) = artifacts_dir() else { return };
    let cfg = ServerConfig {
        workers: 4,
        max_batch: 8,
        batch_timeout_us: 10_000,
        work_stealing: true,
        reorder_depth: 2,
        ..Default::default()
    };
    let server = Server::start(&dir, cfg).expect("start");
    let mut rng = Rng::new(0xC0DE);
    let inputs: Vec<Vec<f32>> = (0..8).map(|_| lstm_input(&mut rng)).collect();
    let solo: Vec<Vec<f32>> = inputs
        .iter()
        .map(|x| server.infer_blocking("edge_lstm", vec![x.clone()], TIMEOUT).unwrap().output)
        .collect();
    let rxs: Vec<_> = inputs
        .iter()
        .map(|x| server.infer_request("edge_lstm", vec![x.clone()]).send().expect("submit"))
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv_timeout(TIMEOUT).expect("recv").expect("chunked execution");
        assert!(resp.batch_size <= 4, "chunk exceeds largest variant");
        assert_eq!(resp.output, solo[i], "request {i} bit-exact through chunking");
    }
    let snap = server.metrics();
    assert_eq!(snap.failed, 0);
    assert_eq!(snap.fifo_violations, 0);
    server.shutdown();
}

#[test]
fn server_responses_identical_with_gemm_on_and_off() {
    // Flipping the config knob must not change a single bit of any
    // response — the safety property that makes the per-sample path a
    // valid benchmark baseline.
    let Some(dir) = artifacts_dir() else { return };
    let mut rng = Rng::new(0xABCD);
    let cnn: Vec<Vec<f32>> = (0..6).map(|_| cnn_input(&mut rng)).collect();
    let lstm: Vec<Vec<f32>> = (0..4).map(|_| lstm_input(&mut rng)).collect();
    let run = |batched: bool| -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let cfg = ServerConfig {
            max_batch: 4,
            batch_timeout_us: 20_000,
            batched_gemm: batched,
            ..Default::default()
        };
        let server = Server::start(&dir, cfg).expect("start");
        // Flood so the batched path actually executes multi-row jobs.
        let crx: Vec<_> = cnn
            .iter()
            .map(|x| server.infer_request("edge_cnn", vec![x.clone()]).send().expect("submit"))
            .collect();
        let lrx: Vec<_> = lstm
            .iter()
            .map(|x| server.infer_request("edge_lstm", vec![x.clone()]).send().expect("submit"))
            .collect();
        let c = crx
            .into_iter()
            .map(|rx| rx.recv_timeout(TIMEOUT).unwrap().unwrap().output)
            .collect();
        let l = lrx
            .into_iter()
            .map(|rx| rx.recv_timeout(TIMEOUT).unwrap().unwrap().output)
            .collect();
        server.shutdown();
        (c, l)
    };
    let (c_on, l_on) = run(true);
    let (c_off, l_off) = run(false);
    assert_eq!(c_on, c_off, "edge_cnn responses must be bit-identical across modes");
    assert_eq!(l_on, l_off, "edge_lstm responses must be bit-identical across modes");
}
