//! End-to-end coordinator tests: router → batcher → PJRT executor.
//!
//! Skipped when artifacts are absent (run `make artifacts`).

use mensa::config::ServerConfig;
use mensa::coordinator::Server;
use std::time::Duration;

fn artifacts_dir() -> Option<String> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(&format!("{dir}/manifest.toml")).exists() {
        Some(dir.to_string())
    } else {
        eprintln!("SKIP: no artifacts; run `make artifacts`");
        None
    }
}

fn cnn_input(seed: usize) -> Vec<f32> {
    (0..32 * 32 * 3).map(|i| ((i + seed * 131) % 17) as f32 / 17.0).collect()
}

const TIMEOUT: Duration = Duration::from_secs(30);

#[test]
fn serves_single_request_with_sim_cost() {
    let Some(dir) = artifacts_dir() else { return };
    let server = Server::start(&dir, ServerConfig::default()).expect("start");
    let resp = server
        .infer_blocking("edge_cnn", vec![cnn_input(0)], TIMEOUT)
        .expect("inference");
    assert_eq!(resp.output.len(), 16);
    assert!(resp.output.iter().all(|x| x.is_finite()));
    // Modeled Mensa-G cost rides along with the real numerics.
    assert!(resp.sim.latency_s > 0.0);
    assert!(resp.sim.energy_j > 0.0);
    assert_eq!(resp.sim.accel_mix.len(), 3);
    server.shutdown();
}

#[test]
fn batches_concurrent_requests() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = ServerConfig { max_batch: 4, batch_timeout_us: 50_000, ..Default::default() };
    let server = Server::start(&dir, cfg).expect("start");
    // Fire 4 requests without waiting: the batcher should coalesce.
    let rxs: Vec<_> = (0..4)
        .map(|i| server.infer_request("edge_cnn", vec![cnn_input(i)]).send().expect("submit"))
        .collect();
    let mut batched = 0;
    for rx in rxs {
        let resp = rx.recv_timeout(TIMEOUT).expect("recv").expect("ok");
        assert_eq!(resp.output.len(), 16);
        if resp.batch_size > 1 {
            batched += 1;
        }
    }
    assert!(batched >= 2, "expected coalescing, got {batched} batched responses");
    let snap = server.metrics();
    assert_eq!(snap.completed, 4);
    assert!(snap.mean_batch > 1.0, "mean batch {}", snap.mean_batch);
    server.shutdown();
}

#[test]
fn batched_results_match_solo_results() {
    let Some(dir) = artifacts_dir() else { return };
    // Solo run.
    let server = Server::start(&dir, ServerConfig::default()).expect("start");
    let solo = server
        .infer_blocking("edge_cnn", vec![cnn_input(7)], TIMEOUT)
        .expect("solo")
        .output;
    // Batched run of the same input among others.
    let rxs: Vec<_> = (0..3)
        .map(|i| {
            let x = cnn_input(if i == 1 { 7 } else { i });
            server.infer_request("edge_cnn", vec![x]).send().unwrap()
        })
        .collect();
    let outputs: Vec<Vec<f32>> = rxs
        .into_iter()
        .map(|rx| rx.recv_timeout(TIMEOUT).unwrap().unwrap().output)
        .collect();
    for (a, b) in outputs[1].iter().zip(&solo) {
        assert!((a - b).abs() < 1e-4, "batched {a} vs solo {b}");
    }
    server.shutdown();
}

#[test]
fn serves_all_three_families() {
    let Some(dir) = artifacts_dir() else { return };
    let server = Server::start(&dir, ServerConfig::default()).expect("start");
    let cnn = server.infer_blocking("edge_cnn", vec![cnn_input(1)], TIMEOUT).unwrap();
    assert_eq!(cnn.output.len(), 16);
    let lstm_in: Vec<f32> = (0..8 * 128).map(|i| (i % 5) as f32 / 5.0).collect();
    let lstm = server.infer_blocking("edge_lstm", vec![lstm_in], TIMEOUT).unwrap();
    assert_eq!(lstm.output.len(), 256);
    let joint = server
        .infer_blocking("joint", vec![vec![0.1; 128], vec![0.2; 128]], TIMEOUT)
        .unwrap();
    assert_eq!(joint.output.len(), 256);
    // Sim costs differ per family: LSTM proxies are far more expensive
    // than the CNN on the modeled baseline-relative scale.
    assert!(lstm.sim.energy_j != cnn.sim.energy_j);
    server.shutdown();
}

#[test]
fn unknown_family_fails_cleanly() {
    let Some(dir) = artifacts_dir() else { return };
    let server = Server::start(&dir, ServerConfig::default()).expect("start");
    let err = server.infer_blocking("bert", vec![vec![0.0; 4]], TIMEOUT).unwrap_err();
    assert!(format!("{err:#}").contains("no variant"), "{err:#}");
    let snap = server.metrics();
    assert_eq!(snap.failed, 1);
    server.shutdown();
}

#[test]
fn malformed_request_fails_without_poisoning_server() {
    let Some(dir) = artifacts_dir() else { return };
    let server = Server::start(&dir, ServerConfig::default()).expect("start");
    // Wrong input size.
    let err = server.infer_blocking("edge_cnn", vec![vec![0.0; 3]], TIMEOUT).unwrap_err();
    assert!(format!("{err:#}").contains("elements"), "{err:#}");
    // Server still healthy afterwards.
    let ok = server.infer_blocking("edge_cnn", vec![cnn_input(2)], TIMEOUT).expect("healthy");
    assert_eq!(ok.output.len(), 16);
    server.shutdown();
}

#[test]
fn backpressure_rejects_when_queue_full() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = ServerConfig {
        max_batch: 8,
        batch_timeout_us: 200_000,
        queue_depth: 2,
        ..Default::default()
    };
    let server = Server::start(&dir, cfg).expect("start");
    // Flood far beyond the queue depth; at least one must be rejected.
    let mut rejections = 0;
    let mut accepted = Vec::new();
    for i in 0..64 {
        match server.infer_request("edge_cnn", vec![cnn_input(i)]).send() {
            Ok(rx) => accepted.push(rx),
            Err(_) => rejections += 1,
        }
    }
    assert!(rejections > 0, "queue_depth=2 must reject under a 64-request flood");
    for rx in accepted {
        let _ = rx.recv_timeout(TIMEOUT);
    }
    assert!(server.metrics().rejected > 0);
    server.shutdown();
}

#[test]
fn oversized_lstm_batch_splits_across_variants() {
    // edge_lstm's largest compiled variant is b4; a flood of 8 must be
    // chunked by the executor, not failed — every request replied to,
    // with `batch_size` reflecting the executed chunk, not the
    // original oversized job.
    let Some(dir) = artifacts_dir() else { return };
    let cfg = ServerConfig { max_batch: 8, batch_timeout_us: 50_000, ..Default::default() };
    let server = Server::start(&dir, cfg).expect("start");
    let lstm_in = |s: usize| -> Vec<f32> {
        (0..8 * 128).map(|i| ((i + s) % 9) as f32 / 9.0).collect()
    };
    let rxs: Vec<_> = (0..8)
        .map(|i| server.infer_request("edge_lstm", vec![lstm_in(i)]).send().expect("submit"))
        .collect();
    for rx in rxs {
        let resp = rx.recv_timeout(TIMEOUT).expect("recv").expect("chunked execution");
        assert_eq!(resp.output.len(), 256);
        assert!(
            resp.batch_size <= 4,
            "batch_size {} exceeds the largest compiled variant",
            resp.batch_size
        );
    }
    let snap = server.metrics();
    assert_eq!(snap.failed, 0);
    assert_eq!(snap.completed, 8, "all chunked requests replied");
    server.shutdown();
}

fn lstm_seq(seed: usize) -> Vec<f32> {
    (0..8 * 128).map(|i| (((i * 7 + seed * 131) % 23) as f32 - 11.0) / 23.0).collect()
}

#[test]
fn mixed_families_round_trip_on_worker_pool() {
    // The executor-pool acceptance test: with workers >= 2, a mixed
    // edge_cnn + edge_lstm load completes with per-family response
    // ordering preserved. Ordering is verified through content: each
    // response must equal its own request's solo output, so any
    // cross-request mixup inside a batch (including the time-major
    // LSTM interleaving bug), between chunks, or between workers would
    // mismatch.
    let Some(dir) = artifacts_dir() else { return };
    let cfg = ServerConfig {
        workers: 2,
        max_batch: 4,
        batch_timeout_us: 20_000,
        ..Default::default()
    };
    let server = Server::start(&dir, cfg).expect("start");

    // Solo baselines (sequential, batch of 1 each).
    let solo_cnn: Vec<Vec<f32>> = (0..8)
        .map(|i| server.infer_blocking("edge_cnn", vec![cnn_input(i)], TIMEOUT).unwrap().output)
        .collect();
    let solo_lstm: Vec<Vec<f32>> = (0..8)
        .map(|i| server.infer_blocking("edge_lstm", vec![lstm_seq(i)], TIMEOUT).unwrap().output)
        .collect();

    // Interleaved flood across both families.
    let mut rxs = Vec::new();
    for i in 0..8 {
        rxs.push((
            "edge_cnn",
            i,
            server.infer_request("edge_cnn", vec![cnn_input(i)]).send().expect("submit"),
        ));
        rxs.push((
            "edge_lstm",
            i,
            server.infer_request("edge_lstm", vec![lstm_seq(i)]).send().expect("submit"),
        ));
    }
    let mut batched = 0;
    for (family, i, rx) in rxs {
        let resp = rx.recv_timeout(TIMEOUT).expect("recv").expect("ok");
        let solo = if family == "edge_cnn" { &solo_cnn[i] } else { &solo_lstm[i] };
        assert_eq!(resp.output.len(), solo.len(), "{family} request {i}");
        for (a, b) in resp.output.iter().zip(solo) {
            assert!(
                (a - b).abs() < 1e-5,
                "{family} request {i}: batched {a} vs solo {b} — response misrouted"
            );
        }
        if resp.batch_size > 1 {
            batched += 1;
        }
        assert!(resp.sim.energy_j > 0.0, "modeled cost rides along");
    }
    assert!(batched >= 8, "expected coalescing under the flood, got {batched}");

    let snap = server.metrics();
    assert_eq!(snap.completed, 32, "16 solo + 16 flooded");
    assert_eq!(snap.failed, 0);
    let by_family: std::collections::HashMap<_, _> =
        snap.completed_by_family.iter().cloned().collect();
    assert_eq!(by_family.get("edge_cnn"), Some(&16));
    assert_eq!(by_family.get("edge_lstm"), Some(&16));
    server.shutdown();
}

#[test]
fn batched_sim_cost_is_amortized_across_the_batch() {
    // A solo request carries the full modeled family cost; a request
    // riding in a batch of n carries 1/n of it (no double counting in
    // the energy accounting).
    let Some(dir) = artifacts_dir() else { return };
    let cfg = ServerConfig { max_batch: 4, batch_timeout_us: 50_000, ..Default::default() };
    let server = Server::start(&dir, cfg).expect("start");
    let solo = server.infer_blocking("edge_cnn", vec![cnn_input(0)], TIMEOUT).expect("solo");
    assert_eq!(solo.batch_size, 1);
    assert!(solo.sim.energy_j > 0.0);

    let rxs: Vec<_> = (0..4)
        .map(|i| server.infer_request("edge_cnn", vec![cnn_input(i)]).send().expect("submit"))
        .collect();
    let resps: Vec<_> = rxs
        .into_iter()
        .map(|rx| rx.recv_timeout(TIMEOUT).expect("recv").expect("ok"))
        .collect();
    let mut batched_checked = 0;
    for resp in &resps {
        let expected = solo.sim.energy_j / resp.batch_size as f64;
        assert!(
            (resp.sim.energy_j - expected).abs() < 1e-12 * solo.sim.energy_j.max(1.0),
            "batch {}: energy {} != full {} / {}",
            resp.batch_size,
            resp.sim.energy_j,
            solo.sim.energy_j,
            resp.batch_size
        );
        let lat_expected = solo.sim.latency_s / resp.batch_size as f64;
        assert!((resp.sim.latency_s - lat_expected).abs() < 1e-12);
        if resp.batch_size > 1 {
            batched_checked += 1;
        }
    }
    assert!(batched_checked >= 2, "flood did not coalesce; amortization untested");
    server.shutdown();
}
