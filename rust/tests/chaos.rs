//! Chaos suite: the fault-tolerance contracts, end to end through the
//! server, with deterministic faults injected at the `Backend` seam:
//!
//! * **transient retry** — injected execute errors and kernel panics
//!   are retried within the bounded attempt budget; every delivered
//!   response stays bit-exact and in order, and nothing fails;
//! * **supervised respawn** — injected worker deaths (a panic outside
//!   the per-chunk guard) are survived: the supervisor re-queues the
//!   dead worker's family lease and respawns it under the same class
//!   binding (`workers_respawned`), losing no requests;
//! * **blackout failover** — a whole device class failing transiently
//!   trips its circuit breaker; placed families re-route to the
//!   next-best class in their modeled-latency ranking and the run
//!   completes bit-exact with FIFO intact (the acceptance scenario);
//! * **brownout failover** — the breaker also trips on observed
//!   latency alone (windows inflated past the degraded ratio), with
//!   zero failures and zero retries;
//! * **admission pricing** — under a roster, the modeled admission
//!   wait prices the *aggregate* drain rate across spill-eligible
//!   classes, not just the placed class;
//! * **shutdown during drain** — worker deaths racing `shutdown()`
//!   (with the escalator holding in-flight jobs) can neither strand a
//!   lease nor hang the join;
//! * **conservation property** — across batch sizes 1/4/8 on flat and
//!   roster pools, `completed + jobs_shed + jobs_expired + failed ==
//!   offered`, `fifo_violations == 0`, and delivered responses are
//!   bit-exact against a fault-free run.
//!
//! Fault plans are configured per server (never via `MENSA_FAULT`, so
//! parallel tests cannot interfere); CI's chaos leg overlays a pinned
//! seed through the env, which these assertions tolerate by
//! construction (wide probabilistic margins or rate-1.0 determinism).

use mensa::config::{DeviceClass, DeviceClassSpec, FamilyPolicy, OverloadPolicy, ServerConfig};
use mensa::coordinator::{device, DeviceProfile, Server};
use mensa::runtime::{FaultPlan, Precision};
use mensa::util::rng::Rng;
use std::fmt::Write as _;
use std::sync::{mpsc, OnceLock};
use std::time::Duration;

const TIMEOUT: Duration = Duration::from_secs(30);

fn artifacts_dir() -> Option<String> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(&format!("{dir}/manifest.toml")).exists() {
        Some(dir.to_string())
    } else {
        eprintln!("SKIP: no artifacts; run `make artifacts`");
        None
    }
}

fn cnn_input(rng: &mut Rng) -> Vec<f32> {
    (0..32 * 32 * 3).map(|_| rng.range_f64(0.0, 1.0) as f32).collect()
}

fn lstm_input(rng: &mut Rng) -> Vec<f32> {
    (0..8 * 128).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect()
}

/// Batch-1 reference outputs from a fresh fault-free default server —
/// the bit-exact target every faulted run must reproduce (batching,
/// retries, respawns, and failover are all numerics-invariant).
fn solo_outputs(dir: &str, reqs: &[(&str, Vec<f32>)]) -> Vec<Vec<f32>> {
    let server = Server::start(dir, ServerConfig::default()).expect("solo server");
    let out = reqs
        .iter()
        .map(|(family, x)| {
            server.infer_blocking(family, vec![x.clone()], TIMEOUT).expect("solo").output
        })
        .collect();
    server.shutdown();
    out
}

/// The families the roster tests model (the serving artifacts' set).
fn roster_families() -> Vec<String> {
    vec!["edge_cnn".into(), "edge_lstm".into(), "joint".into()]
}

/// Two-class Pascal/Pavlov roster scaled so the slowest modeled
/// (class, family) window is `slowest` — test-friendly absolute
/// timing, heterogeneity (and with it placement and failover ranking)
/// preserved. Returns the scaled specs plus their profiles, built
/// exactly as `Server::start` builds them (profile index == class
/// index).
fn calibrated_roster(slowest: Duration) -> (Vec<DeviceClassSpec>, Vec<DeviceProfile>) {
    let families = roster_families();
    let probe = vec![
        DeviceClassSpec { class: DeviceClass::Pascal, workers: 1, latency_scale: 1.0 },
        DeviceClassSpec { class: DeviceClass::Pavlov, workers: 1, latency_scale: 1.0 },
    ];
    let base = device::build_profiles(&probe, &families, Duration::ZERO);
    let max_base = base
        .iter()
        .flat_map(|p| families.iter().map(move |f| p.base_latency_s(f)))
        .fold(0.0f64, f64::max);
    let scale = slowest.as_secs_f64() / max_base.max(1e-12);
    let roster: Vec<DeviceClassSpec> = probe
        .into_iter()
        .map(|mut spec| {
            spec.latency_scale = scale;
            spec
        })
        .collect();
    let profiles = device::build_profiles(&roster, &families, Duration::ZERO);
    (roster, profiles)
}

/// The class index `family` is placed on (rank 0) and its first
/// failover target (rank 1), per the same ranking the breaker walks.
fn primary_and_backup(profiles: &[DeviceProfile], family: &str) -> (usize, usize) {
    let ranking = device::placement_ranking(profiles, &roster_families());
    let order = &ranking[family];
    (order[0], order[1])
}

#[test]
fn transient_faults_retry_to_bit_exact_completion() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rng = Rng::new(0xFA17);
    let reqs: Vec<(&str, Vec<f32>)> = (0..32).map(|_| ("edge_cnn", cnn_input(&mut rng))).collect();
    let solo = solo_outputs(&dir, &reqs);

    // Three workers spreading one family's chunks through the reorder
    // buffer (the hardest ordering regime for front-requeued retries),
    // under a heavy mix of injected errors, caught panics, and stalls.
    // The attempt budget is far above any plausible consecutive-fault
    // streak, so nothing may fail.
    let cfg = ServerConfig {
        workers: 3,
        max_batch: 1,
        batch_timeout_us: 1_000,
        reorder_depth: 3,
        retry_max: 24,
        fault: Some(FaultPlan {
            seed: 0xFA17,
            exec_error_rate: 0.3,
            panic_rate: 0.2,
            stall_rate: 0.1,
            stall_us: 200,
            ..FaultPlan::default()
        }),
        ..Default::default()
    };
    let server = Server::start(&dir, cfg).expect("start");
    let rxs: Vec<_> = reqs
        .iter()
        .map(|(family, x)| server.infer_request(family, vec![x.clone()]).send().expect("submit"))
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv_timeout(TIMEOUT).expect("recv").expect("retries must absorb faults");
        assert_eq!(resp.output, solo[i], "request {i}: bit-exact through retries");
    }
    let snap = server.metrics();
    assert_eq!(snap.completed, 32);
    assert_eq!(snap.failed, 0, "every injected fault is transient and within budget");
    assert!(snap.jobs_retried >= 1, "a 0.3 error rate over 32 chunks must retry");
    assert_eq!(snap.fifo_violations, 0, "front-requeued retries preserve delivery order");
    server.shutdown();
}

#[test]
fn worker_deaths_respawn_without_losing_requests() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rng = Rng::new(0xDEAD);
    let reqs: Vec<(&str, Vec<f32>)> = (0..8).map(|_| ("edge_cnn", cnn_input(&mut rng))).collect();
    let solo = solo_outputs(&dir, &reqs);

    // death_rate 1.0: every family take dies while the budget lasts.
    // One family means takes are serialized on the lease, so exactly
    // max_deaths takes die — each time the supervisor must release and
    // re-offer the held queue and respawn — before take #4 serves the
    // whole backlog.
    let cfg = ServerConfig {
        workers: 2,
        max_batch: 1,
        batch_timeout_us: 1_000,
        fault: Some(FaultPlan { seed: 0xDEAD, death_rate: 1.0, max_deaths: 3, ..FaultPlan::default() }),
        ..Default::default()
    };
    let server = Server::start(&dir, cfg).expect("start");
    let rxs: Vec<_> = reqs
        .iter()
        .map(|(family, x)| server.infer_request(family, vec![x.clone()]).send().expect("submit"))
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv_timeout(TIMEOUT).expect("recv").expect("deaths must not lose requests");
        assert_eq!(resp.output, solo[i], "request {i}: bit-exact across respawns");
    }
    let snap = server.metrics();
    assert_eq!(snap.workers_respawned, 3, "every budgeted death respawned");
    assert_eq!(snap.completed, 8);
    assert_eq!(snap.failed, 0, "a death at lease-take touches no in-flight chunk");
    assert_eq!(snap.fifo_violations, 0);
    server.shutdown();
}

#[test]
fn blackout_fails_over_and_completes_bit_exact() {
    let Some(dir) = artifacts_dir() else { return };
    let (roster, profiles) = calibrated_roster(Duration::from_millis(5));
    let (primary, backup) = primary_and_backup(&profiles, "edge_cnn");
    let primary_label = profiles[primary].class().to_string();
    let backup_label = profiles[backup].class().to_string();
    let mut rng = Rng::new(0xB1AC);
    let reqs: Vec<(&str, Vec<f32>)> = (0..16).map(|_| ("edge_cnn", cnn_input(&mut rng))).collect();
    let solo = solo_outputs(&dir, &reqs);

    // The acceptance scenario: the placed class is blacked out (every
    // execute fails transiently) AND workers die mid-run. Two strikes
    // trip the breaker; the hour-long cooldown keeps it open for the
    // whole test so no half-open probe reverts routing underneath the
    // assertions. The retry budget must outlast the strikes a chunk
    // burns before the trip re-routes its family.
    let cfg = ServerConfig {
        max_batch: 1,
        batch_timeout_us: 1_000,
        devices: roster,
        spill_after_us: 50_000,
        retry_max: 10,
        breaker_threshold: 2,
        breaker_cooldown_us: 3_600_000_000,
        fault: Some(FaultPlan {
            seed: 0xB1AC,
            blackout_class: Some(primary_label.clone()),
            death_rate: 1.0,
            max_deaths: 2,
            ..FaultPlan::default()
        }),
        ..Default::default()
    };
    let server = Server::start(&dir, cfg).expect("start");
    let rxs: Vec<_> = reqs
        .iter()
        .map(|(family, x)| server.infer_request(family, vec![x.clone()]).send().expect("submit"))
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv_timeout(TIMEOUT).expect("recv").expect("failover must serve it");
        assert_eq!(resp.output, solo[i], "request {i}: bit-exact across the failover");
    }
    let snap = server.metrics();
    assert_eq!(snap.completed, 16, "a blacked-out class loses no requests");
    assert_eq!(snap.failed, 0);
    assert_eq!(snap.jobs_shed, 0);
    assert_eq!(snap.jobs_expired, 0);
    assert_eq!(snap.fifo_violations, 0, "failover preserves per-family order");
    assert!(snap.breaker_trips >= 1, "consecutive blackout failures must trip the breaker");
    assert!(snap.failovers >= 1, "the placed family must re-route off the dead class");
    assert!(snap.jobs_retried >= 1, "pre-trip failures must be retried, not failed");
    assert_eq!(snap.workers_respawned, 2, "both budgeted deaths respawned");
    let primary_jobs = snap
        .jobs_by_device
        .iter()
        .find(|(class, _)| class == &primary_label)
        .map_or(0, |(_, n)| *n);
    assert_eq!(primary_jobs, 0, "no job can complete on the blacked-out class");
    let backup_jobs = snap
        .jobs_by_device
        .iter()
        .find(|(class, _)| class == &backup_label)
        .map_or(0, |(_, n)| *n);
    assert_eq!(backup_jobs, 16, "every job lands on the failover target");
    server.shutdown();
}

#[test]
fn brownout_trips_the_breaker_on_latency_alone() {
    let Some(dir) = artifacts_dir() else { return };
    let (roster, profiles) = calibrated_roster(Duration::from_millis(2));
    let (primary, _) = primary_and_backup(&profiles, "edge_cnn");
    let primary_label = profiles[primary].class().to_string();
    let mut rng = Rng::new(0xB708);
    let reqs: Vec<(&str, Vec<f32>)> = (0..8).map(|_| ("edge_cnn", cnn_input(&mut rng))).collect();
    let solo = solo_outputs(&dir, &reqs);

    // Brownout inflates the placed class's observed windows 8x — far
    // past the breaker's degraded ratio — while every execute still
    // SUCCEEDS. The breaker must trip on latency health alone: zero
    // failures, zero retries, and the family still fails over.
    let cfg = ServerConfig {
        max_batch: 1,
        batch_timeout_us: 1_000,
        devices: roster,
        breaker_threshold: 2,
        breaker_cooldown_us: 3_600_000_000,
        fault: Some(FaultPlan {
            seed: 0xB708,
            brownout_class: Some(primary_label),
            brownout_scale: 8.0,
            ..FaultPlan::default()
        }),
        ..Default::default()
    };
    let server = Server::start(&dir, cfg).expect("start");
    let rxs: Vec<_> = reqs
        .iter()
        .map(|(family, x)| server.infer_request(family, vec![x.clone()]).send().expect("submit"))
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv_timeout(TIMEOUT).expect("recv").expect("brownout never fails");
        assert_eq!(resp.output, solo[i], "request {i}: bit-exact under brownout");
    }
    let snap = server.metrics();
    assert_eq!(snap.completed, 8);
    assert_eq!(snap.failed, 0);
    assert_eq!(snap.jobs_retried, 0, "slow is not broken: nothing to retry");
    assert!(snap.breaker_trips >= 1, "the degraded-latency ratio must trip the breaker");
    assert!(snap.failovers >= 1, "the browned-out class's family must re-route");
    assert_eq!(snap.fifo_violations, 0);
    server.shutdown();
}

#[test]
fn admission_prices_spill_eligible_classes_not_just_the_placed_one() {
    let Some(dir) = artifacts_dir() else { return };
    // Scale so the PLACED class's edge_cnn window is exactly 20 ms;
    // the other class is slower but still drains the queue in
    // parallel past the spill threshold.
    let families = roster_families();
    let probe = vec![
        DeviceClassSpec { class: DeviceClass::Pascal, workers: 1, latency_scale: 1.0 },
        DeviceClassSpec { class: DeviceClass::Pavlov, workers: 1, latency_scale: 1.0 },
    ];
    let base = device::build_profiles(&probe, &families, Duration::ZERO);
    let min_base = base
        .iter()
        .map(|p| p.base_latency_s("edge_cnn"))
        .fold(f64::INFINITY, f64::min);
    let scale = Duration::from_millis(20).as_secs_f64() / min_base.max(1e-12);
    let roster: Vec<DeviceClassSpec> = probe
        .into_iter()
        .map(|mut spec| {
            spec.latency_scale = scale;
            spec
        })
        .collect();
    let profiles = device::build_profiles(&roster, &families, Duration::ZERO);
    let windows: Vec<f64> =
        profiles.iter().map(|p| p.window("edge_cnn", 1).as_secs_f64()).collect();
    let placed = windows.iter().copied().fold(f64::INFINITY, f64::min);
    // The aggregate service estimate the fixed admission model uses:
    // the inverse of the classes' summed drain rates (1 worker each).
    let aggregate = 1.0 / windows.iter().map(|w| 1.0 / w).sum::<f64>();
    assert!(aggregate < placed, "two drains are faster than one");

    let cfg = ServerConfig {
        max_batch: 1,
        batch_timeout_us: 1_000,
        devices: roster,
        overload: OverloadPolicy::Shed,
        ..Default::default()
    };
    let server = Server::start(&dir, cfg).expect("start");
    let mut rng = Rng::new(0xAD01);

    // Below the aggregate estimate: unmeetable even with every class
    // draining, so admission sheds.
    let err = server
        .infer_request("edge_cnn", vec![cnn_input(&mut rng)])
        .deadline(Duration::from_secs_f64(aggregate / 2.0))
        .send()
        .expect_err("half the aggregate drain estimate must shed");
    assert!(format!("{err:#}").contains("admission shed"), "{err:#}");

    // Between the aggregate estimate and the placed class's window:
    // the placed class ALONE could never meet it, but the roster's
    // summed drain rate can — pricing only the placed class (the old
    // model) would wrongly shed this.
    let rx = server
        .infer_request("edge_cnn", vec![cnn_input(&mut rng)])
        .deadline(Duration::from_secs_f64((aggregate + placed) / 2.0))
        .send()
        .expect("a budget the aggregate drain rate covers must be admitted");
    let _ = rx.recv_timeout(TIMEOUT).expect("terminal reply");
    let snap = server.metrics();
    assert_eq!(snap.jobs_shed, 1, "only the sub-aggregate budget shed");
    assert_eq!(
        snap.completed + snap.jobs_expired,
        1,
        "the admitted request ran (or expired at dequeue on a slow host) — never shed"
    );
    server.shutdown();
}

/// Write a synthetic two-family manifest (shared input shape) once per
/// process: `tiny` (12 → 6) escalates to `big` (12 → 20).
fn escalation_manifest_dir() -> &'static str {
    static DIR: OnceLock<String> = OnceLock::new();
    DIR.get_or_init(|| {
        let dir = std::env::temp_dir().join(format!("mensa_chaos_{}", std::process::id()));
        std::fs::create_dir_all(&dir).expect("create manifest dir");
        let mut m = String::from("# Generated by chaos.rs — escalation pair.\n");
        for (fam, d_out) in [("tiny", 6usize), ("big", 20usize)] {
            for b in [1usize, 4] {
                let _ = write!(
                    m,
                    "\n[[artifact]]\nname = \"{fam}_b{b}\"\nfile = \"{fam}_b{b}.hlo.txt\"\n\
                     num_inputs = 1\ninput0_shape = \"{b}x12\"\ninput0_batch_axis = 0\n\
                     output_shape = \"{b}x{d_out}\"\noutput_batch_axis = 0\n\
                     sha256 = \"referencebackend\"\n"
                );
            }
        }
        std::fs::write(dir.join("manifest.toml"), m).expect("write manifest");
        dir.to_str().expect("utf8 temp dir").to_string()
    })
}

#[test]
fn shutdown_during_drain_survives_deaths_and_escalation() {
    let dir = escalation_manifest_dir();
    let inputs: Vec<Vec<f32>> = (0..8)
        .map(|r| (0..12).map(|i| (((i * 29 + r * 11 + 5) % 97) as f32 / 97.0) - 0.5).collect())
        .collect();
    // Both possible terminal outputs per request: the escalated big
    // result (escalator still armed) or the small fallback (disarm won
    // the race during shutdown). Either is a valid drain — a dropped
    // reply or a hung join is the bug this test pins.
    let solo_server = Server::start(dir, ServerConfig::default()).expect("solo");
    let solo_tiny: Vec<Vec<f32>> = inputs
        .iter()
        .map(|x| solo_server.infer_blocking("tiny", vec![x.clone()], TIMEOUT).unwrap().output)
        .collect();
    let solo_big: Vec<Vec<f32>> = inputs
        .iter()
        .map(|x| solo_server.infer_blocking("big", vec![x.clone()], TIMEOUT).unwrap().output)
        .collect();
    solo_server.shutdown();

    // Every tiny request escalates (threshold 1.0), every early
    // family take dies (rate 1.0, budget 2) — and shutdown() races
    // the whole drain from another thread. A worker dying during the
    // drain must not strand its re-queued lease; the respawned worker
    // drains it and exits when the pool closes.
    let cfg = ServerConfig {
        workers: 2,
        max_batch: 1,
        batch_timeout_us: 1_000,
        families: vec![FamilyPolicy {
            name: "tiny".into(),
            priority: 0,
            escalate_to: Some("big".into()),
            precision: Precision::F32,
        }],
        escalation_threshold: 1.0,
        fault: Some(FaultPlan { seed: 0x5D0D, death_rate: 1.0, max_deaths: 2, ..FaultPlan::default() }),
        ..Default::default()
    };
    let server = Server::start(dir, cfg).expect("start");
    let rxs: Vec<_> = inputs
        .iter()
        .map(|x| server.infer_request("tiny", vec![x.clone()]).send().expect("submit"))
        .collect();
    let (done_tx, done_rx) = mpsc::channel();
    std::thread::spawn(move || {
        server.shutdown();
        let _ = done_tx.send(());
    });
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx
            .recv_timeout(TIMEOUT)
            .expect("every admitted request gets a terminal reply through the racing shutdown")
            .expect("drain serves, never errors");
        assert!(
            resp.output == solo_big[i] || resp.output == solo_tiny[i],
            "request {i}: must be the escalated big result or the small fallback"
        );
    }
    done_rx
        .recv_timeout(TIMEOUT)
        .expect("shutdown() must join every thread — respawned workers included");
}

#[test]
fn faulted_serving_conserves_requests_and_stays_bit_exact() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rng = Rng::new(0xC0DE);
    let reqs: Vec<(&str, Vec<f32>)> = (0..12)
        .map(|i| {
            if i % 2 == 0 {
                ("edge_cnn", cnn_input(&mut rng))
            } else {
                ("edge_lstm", lstm_input(&mut rng))
            }
        })
        .collect();
    let solo = solo_outputs(&dir, &reqs);
    let (roster, _) = calibrated_roster(Duration::from_millis(2));

    // (max_batch, roster?, reorder_depth): the batch axis the issue
    // names, on both pool shapes, with the reorder buffer exercised
    // where it composes (flat legs).
    let legs: [(usize, bool, usize); 6] =
        [(1, false, 2), (4, false, 2), (8, false, 0), (1, true, 0), (4, true, 0), (8, true, 0)];
    for (leg, &(max_batch, use_roster, reorder_depth)) in legs.iter().enumerate() {
        let cfg = ServerConfig {
            workers: 2,
            max_batch,
            batch_timeout_us: 2_000,
            reorder_depth,
            devices: if use_roster { roster.clone() } else { Vec::new() },
            overload: OverloadPolicy::Shed,
            retry_max: 12,
            fault: Some(FaultPlan {
                seed: 0xC0DE + leg as u64,
                exec_error_rate: 0.25,
                panic_rate: 0.1,
                stall_rate: 0.1,
                stall_us: 200,
                ..FaultPlan::default()
            }),
            ..Default::default()
        };
        let server = Server::start(&dir, cfg).expect("start");
        let rxs: Vec<_> = reqs
            .iter()
            .map(|(family, x)| {
                server.infer_request(family, vec![x.clone()]).send().expect("submit")
            })
            .collect();
        let mut delivered = 0u64;
        let mut shed = 0u64;
        for (i, rx) in rxs.into_iter().enumerate() {
            match rx.recv_timeout(TIMEOUT).expect("terminal reply") {
                Ok(resp) => {
                    delivered += 1;
                    assert_eq!(
                        resp.output, solo[i],
                        "leg {leg} (batch {max_batch}, roster {use_roster}): request {i} \
                         must be bit-exact vs the fault-free run"
                    );
                }
                Err(e) => {
                    shed += 1;
                    assert!(
                        format!("{e:#}").contains("shed"),
                        "leg {leg}: only overload shedding may refuse a request, got {e:#}"
                    );
                }
            }
        }
        let snap = server.metrics();
        assert_eq!(snap.completed, delivered, "leg {leg}");
        assert_eq!(snap.jobs_shed, shed, "leg {leg}");
        assert_eq!(
            snap.completed + snap.jobs_shed + snap.jobs_expired + snap.failed,
            12,
            "leg {leg}: conservation — every offered request lands in exactly one bucket"
        );
        assert_eq!(snap.failed, 0, "leg {leg}: transient faults within budget never fail");
        assert_eq!(snap.fifo_violations, 0, "leg {leg}: retries preserve per-family order");
        server.shutdown();
    }
}
