//! Integration tests for the PJRT runtime over real artifacts.
//!
//! These require `make artifacts` to have run; they are skipped (with a
//! loud message) when `artifacts/manifest.toml` is absent so that
//! `cargo test` stays green on a fresh checkout.

use mensa::runtime::Runtime;

fn artifacts_dir() -> Option<String> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(&format!("{dir}/manifest.toml")).exists() {
        Some(dir.to_string())
    } else {
        eprintln!("SKIP: no artifacts; run `make artifacts`");
        None
    }
}

#[test]
fn loads_all_artifacts_and_reports_platform() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).expect("runtime load");
    assert_eq!(rt.platform(), "cpu");
    let names = rt.model_names();
    assert!(names.contains(&"edge_cnn_b1"), "{names:?}");
    assert!(names.contains(&"edge_lstm_b1"), "{names:?}");
    assert!(names.contains(&"joint_b1"), "{names:?}");
}

#[test]
fn cnn_executes_with_correct_shape_and_determinism() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).expect("runtime load");
    let input: Vec<f32> = (0..32 * 32 * 3).map(|i| (i % 7) as f32 / 7.0).collect();
    let out1 = rt.execute("edge_cnn_b1", &[input.clone()]).expect("exec");
    assert_eq!(out1.len(), 16);
    assert!(out1.iter().all(|x| x.is_finite()));
    let out2 = rt.execute("edge_cnn_b1", &[input]).expect("exec");
    assert_eq!(out1, out2, "same input, same output");
}

#[test]
fn batched_cnn_matches_single_requests() {
    // The batcher's correctness contract: batch results equal
    // per-request results row by row.
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).expect("runtime load");
    let reqs: Vec<Vec<f32>> = (0..4)
        .map(|r| (0..32 * 32 * 3).map(|i| ((i + r * 31) % 11) as f32 / 11.0).collect())
        .collect();
    let mut batched_input = Vec::new();
    for r in &reqs {
        batched_input.extend_from_slice(r);
    }
    let batched = rt.execute("edge_cnn_b4", &[batched_input]).expect("batched exec");
    for (r, req) in reqs.iter().enumerate() {
        let single = rt.execute("edge_cnn_b1", &[req.clone()]).expect("single exec");
        let row = &batched[r * 16..(r + 1) * 16];
        for (a, b) in row.iter().zip(&single) {
            assert!((a - b).abs() < 1e-4, "row {r}: {a} vs {b}");
        }
    }
}

#[test]
fn lstm_is_sequence_sensitive() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).expect("runtime load");
    let t = 8;
    let d = 128;
    let fwd: Vec<f32> = (0..t * d).map(|i| ((i % 13) as f32 - 6.0) / 13.0).collect();
    let mut rev = vec![0.0f32; t * d];
    for step in 0..t {
        rev[step * d..(step + 1) * d].copy_from_slice(&fwd[(t - 1 - step) * d..(t - step) * d]);
    }
    let out_f = rt.execute("edge_lstm_b1", &[fwd]).expect("exec fwd");
    let out_r = rt.execute("edge_lstm_b1", &[rev]).expect("exec rev");
    assert_eq!(out_f.len(), 256);
    assert!(
        out_f.iter().zip(&out_r).any(|(a, b)| (a - b).abs() > 1e-5),
        "LSTM output must depend on sequence order"
    );
}

#[test]
fn joint_takes_two_inputs() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).expect("runtime load");
    let enc: Vec<f32> = (0..128).map(|i| (i as f32) / 128.0).collect();
    let pred: Vec<f32> = (0..128).map(|i| (128 - i) as f32 / 128.0).collect();
    let out = rt.execute("joint_b1", &[enc, pred]).expect("exec");
    assert_eq!(out.len(), 256);
}

#[test]
fn wrong_input_size_is_rejected() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).expect("runtime load");
    let err = rt.execute("edge_cnn_b1", &[vec![0.0; 5]]).unwrap_err();
    assert!(format!("{err:#}").contains("elements"), "{err:#}");
    let err = rt.execute("joint_b1", &[vec![0.0; 128]]).unwrap_err();
    assert!(format!("{err:#}").contains("inputs"), "{err:#}");
}

#[test]
fn unknown_model_is_an_error() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load(&dir).expect("runtime load");
    assert!(rt.execute("gpt5", &[vec![]]).is_err());
}
