//! Heterogeneous device-class serving, end to end through the server:
//!
//! * **Mensa placement** — with a `[[device]]` roster (Pascal +
//!   Pavlov) and a strict staleness threshold, a skewed CNN+LSTM mix
//!   lands each hot family on the device class the `accel/dataflow`
//!   models prefer for it: every executing worker of a family belongs
//!   to its placed class (`Snapshot::workers_by_family` against the
//!   roster-order worker→class expansion), both classes execute
//!   (`Snapshot::jobs_by_device`), and no transfer is ever charged
//!   because no family crosses classes;
//! * **client-observed FIFO and bit-exact numerics** — every response
//!   under heterogeneous dispatch is bit-identical to a solo run on
//!   the default (roster-free) server, and `fifo_violations == 0`:
//!   the Backend seam changes *timing attribution only*, never
//!   results or ordering;
//! * **spill stealing charges transfers** — with the staleness
//!   threshold at zero, the non-preferred class spills onto a single
//!   hot family's backlog; both classes execute it concurrently, the
//!   [`TransferTracker`] observes the class crossings
//!   (`Snapshot::cross_device_transfers >= 1`), and FIFO still holds
//!   through the reorder buffer;
//! * **roster validation** — a `[[device]]` roster with
//!   `work_stealing = false` is rejected at startup (class-aware
//!   placement is a stealing discipline).

use mensa::config::{DeviceClass, DeviceClassSpec, ServerConfig};
use mensa::coordinator::{device, Server};
use mensa::util::rng::Rng;
use std::time::Duration;

const TIMEOUT: Duration = Duration::from_secs(30);

fn artifacts_dir() -> Option<String> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(&format!("{dir}/manifest.toml")).exists() {
        Some(dir.to_string())
    } else {
        eprintln!("SKIP: no artifacts; run `make artifacts`");
        None
    }
}

fn cnn_input(rng: &mut Rng) -> Vec<f32> {
    (0..32 * 32 * 3).map(|_| rng.range_f64(0.0, 1.0) as f32).collect()
}

fn lstm_input(rng: &mut Rng) -> Vec<f32> {
    (0..8 * 128).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect()
}

/// Solo (batch-1) outputs from a fresh roster-free server — the
/// bit-exact reference every heterogeneous response must reproduce.
fn solo_outputs(dir: &str, family: &str, inputs: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let server = Server::start(dir, ServerConfig::default()).expect("solo server");
    let out = inputs
        .iter()
        .map(|x| server.infer_blocking(family, vec![x.clone()], TIMEOUT).unwrap().output)
        .collect();
    server.shutdown();
    out
}

/// Build a roster whose slowest (class, family) modeled window is
/// `target` — the same calibration the bench harness uses, so the
/// emulated device time stays in test-friendly territory while the
/// classes keep their *relative* heterogeneity (`latency_scale` is
/// uniform across the roster, so the placement argmin is unchanged).
fn scaled_roster(
    classes: &[(DeviceClass, usize)],
    families: &[String],
    target: Duration,
) -> Vec<DeviceClassSpec> {
    let probe: Vec<DeviceClassSpec> = classes
        .iter()
        .map(|&(class, workers)| DeviceClassSpec { class, workers, latency_scale: 1.0 })
        .collect();
    let profiles = device::build_profiles(&probe, families, Duration::ZERO);
    let max_base = profiles
        .iter()
        .flat_map(|p| families.iter().map(move |f| p.base_latency_s(f)))
        .fold(0.0f64, f64::max);
    let scale = target.as_secs_f64() / max_base.max(1e-12);
    probe
        .into_iter()
        .map(|mut spec| {
            spec.latency_scale = scale;
            spec
        })
        .collect()
}

/// Roster-order worker→class expansion — must mirror `Server::start`
/// exactly (worker 0..w0 is class 0, the next w1 are class 1, …).
fn worker_classes(roster: &[DeviceClassSpec]) -> Vec<usize> {
    roster
        .iter()
        .enumerate()
        .flat_map(|(ci, spec)| std::iter::repeat(ci).take(spec.workers.max(1)))
        .collect()
}

#[test]
fn roster_requires_work_stealing() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = ServerConfig {
        work_stealing: false,
        devices: vec![DeviceClassSpec { class: DeviceClass::Pascal, workers: 1, latency_scale: 1.0 }],
        ..Default::default()
    };
    let err = Server::start(&dir, cfg).expect_err("a roster without stealing must be rejected");
    assert!(
        format!("{err:#}").contains("work_stealing"),
        "error should name the offending knob, got: {err:#}"
    );
}

#[test]
fn skewed_mix_lands_hot_families_on_their_preferred_classes() {
    let Some(dir) = artifacts_dir() else { return };
    let families: Vec<String> = vec!["edge_cnn".into(), "edge_lstm".into()];
    // Pascal (compute-dense, LPDDR4) + Pavlov (in-package bandwidth):
    // the paper's CNN-vs-LSTM split. Two workers per class so each
    // family can also spread within its class.
    let roster = scaled_roster(
        &[(DeviceClass::Pascal, 2), (DeviceClass::Pavlov, 2)],
        &families,
        Duration::from_micros(300),
    );
    // The placement the server will derive (argmin over modeled
    // batch-1 latency; a uniform latency_scale cannot change it).
    let place = device::placement(&device::build_profiles(&roster, &families, Duration::ZERO), &families);
    assert_ne!(
        place["edge_cnn"], place["edge_lstm"],
        "the zoo's skew mix must split across the roster — heterogeneity premise: {place:?}"
    );
    let classes = worker_classes(&roster);

    let mut rng = Rng::new(0x4E7E);
    let cnn: Vec<Vec<f32>> = (0..24).map(|_| cnn_input(&mut rng)).collect();
    let lstm: Vec<Vec<f32>> = (0..24).map(|_| lstm_input(&mut rng)).collect();
    let solo_cnn = solo_outputs(&dir, "edge_cnn", &cnn);
    let solo_lstm = solo_outputs(&dir, "edge_lstm", &lstm);

    let cfg = ServerConfig {
        work_stealing: true,
        max_batch: 4,
        batch_timeout_us: 1_000,
        devices: roster,
        transfer_us: 100,
        // Effectively infinite: placement stays strict, nothing spills.
        spill_after_us: 60_000_000,
        ..Default::default()
    };
    let server = Server::start(&dir, cfg).expect("start");
    let submit = |family: &str, x: &Vec<f32>| loop {
        match server.infer_request(family, vec![x.clone()]).send() {
            Ok(rx) => return rx,
            Err(_) => std::thread::sleep(Duration::from_micros(200)),
        }
    };
    // Interleave the two families so both classes are busy at once.
    let mut cnn_rxs = Vec::new();
    let mut lstm_rxs = Vec::new();
    for i in 0..24 {
        cnn_rxs.push(submit("edge_cnn", &cnn[i]));
        lstm_rxs.push(submit("edge_lstm", &lstm[i]));
    }
    for (i, rx) in cnn_rxs.into_iter().enumerate() {
        let resp = rx.recv_timeout(TIMEOUT).expect("recv").expect("ok");
        assert_eq!(resp.output, solo_cnn[i], "cnn request {i} bit-exact across the seam");
    }
    for (i, rx) in lstm_rxs.into_iter().enumerate() {
        let resp = rx.recv_timeout(TIMEOUT).expect("recv").expect("ok");
        assert_eq!(resp.output, solo_lstm[i], "lstm request {i} bit-exact across the seam");
    }

    let snap = server.metrics();
    assert_eq!(snap.fifo_violations, 0, "clients must observe strict FIFO");
    assert_eq!(snap.failed, 0);
    assert_eq!(snap.completed, 48);
    // Both classes executed — two device classes ran concurrently.
    let jobs_on = |class: &str| {
        snap.jobs_by_device.iter().find(|(c, _)| c == class).map(|(_, n)| *n).unwrap_or(0)
    };
    assert!(jobs_on("pascal") > 0, "pascal executed nothing: {:?}", snap.jobs_by_device);
    assert!(jobs_on("pavlov") > 0, "pavlov executed nothing: {:?}", snap.jobs_by_device);
    assert_eq!(
        snap.jobs_by_device.iter().map(|(_, n)| n).sum::<u64>(),
        snap.jobs,
        "every job is attributed to exactly one device class"
    );
    // Placement held: every worker that executed a family belongs to
    // the family's placed class (workers expand in roster order).
    for (family, workers) in &snap.workers_by_family {
        let want = place[family];
        for &w in workers {
            assert_eq!(
                classes[w], want,
                "{family} ran on worker {w} (class {}), placed on class {want}",
                classes[w]
            );
        }
    }
    // No family ever changed class, so no transfer was charged.
    assert_eq!(snap.cross_device_transfers, 0, "strict placement must not cross classes");
    server.shutdown();
}

#[test]
fn zero_staleness_spill_crosses_classes_and_keeps_fifo() {
    let Some(dir) = artifacts_dir() else { return };
    let families: Vec<String> = vec!["edge_lstm".into()];
    // One worker per class, a single hot family: the non-preferred
    // class has nothing of its own, and with the staleness threshold
    // at zero every queued chunk is immediately fair game — so both
    // classes drain the backlog together, and every hop between them
    // is a class crossing the TransferTracker must charge.
    let roster = scaled_roster(
        &[(DeviceClass::Pascal, 1), (DeviceClass::Pavlov, 1)],
        &families,
        Duration::from_millis(1),
    );
    let mut rng = Rng::new(0x5B11);
    let inputs: Vec<Vec<f32>> = (0..32).map(|_| lstm_input(&mut rng)).collect();
    let solo = solo_outputs(&dir, "edge_lstm", &inputs);

    let cfg = ServerConfig {
        work_stealing: true,
        max_batch: 2,
        batch_timeout_us: 500,
        // Depth 4 lets both classes hold the family concurrently; the
        // reorder buffer is what keeps delivery FIFO.
        reorder_depth: 4,
        devices: roster,
        transfer_us: 200,
        spill_after_us: 0,
        ..Default::default()
    };
    let server = Server::start(&dir, cfg).expect("start");
    let rxs: Vec<_> = inputs
        .iter()
        .map(|x| loop {
            match server.infer_request("edge_lstm", vec![x.clone()]).send() {
                Ok(rx) => return rx,
                Err(_) => std::thread::sleep(Duration::from_micros(200)),
            }
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv_timeout(TIMEOUT).expect("recv").expect("ok");
        assert_eq!(resp.output, solo[i], "request {i} bit-exact under cross-class spill");
    }

    let snap = server.metrics();
    assert_eq!(snap.fifo_violations, 0, "spill must never reorder client deliveries");
    assert_eq!(snap.failed, 0);
    assert_eq!(snap.completed, 32);
    assert!(
        snap.jobs_by_device.len() >= 2,
        "zero staleness must pull the idle class in: {:?}",
        snap.jobs_by_device
    );
    // Both classes executed the one family, so its class sequence
    // changed at least once — and never more often than once per job.
    assert!(
        snap.cross_device_transfers >= 1,
        "two classes served one family with no charged transfer"
    );
    assert!(
        snap.cross_device_transfers <= snap.jobs,
        "at most one transfer per executed job ({} > {})",
        snap.cross_device_transfers,
        snap.jobs
    );
    server.shutdown();
}
