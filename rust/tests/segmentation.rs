//! Layer-graph segmentation contracts, end to end through the server
//! (`segment_level = true`):
//!
//! * **bit-exactness** — a flooded multi-stage family (`edge_lstm`:
//!   8 recurrent timesteps; `joint`: 2 dense input blocks) cut into
//!   profiled segments and pipelined across the pool reproduces its
//!   solo (monolithic, batch-1) outputs bit for bit — stage-range
//!   execution hands off exactly the intermediate state a monolithic
//!   call would hold internally;
//! * **FIFO** — the continuation lanes re-impose `(seq, chunk)` order
//!   at every segment boundary, so `Snapshot::fifo_violations` stays
//!   0 while one chunk's segments hop workers;
//! * **pipelining** — under the family-lease discipline
//!   (`reorder_depth = 0`) a single hot stream still reaches >= 2
//!   workers, because each segment lane holds its own lease (the
//!   bench's `layer_pipeline` headline, asserted here functionally);
//! * **accounting** — `segments_executed`, `segment_hops`, and `jobs`
//!   stay consistent (`hops == segments - jobs`; `jobs` counts each
//!   chunk once, on its final segment), on the flat pool and on a
//!   heterogeneous `[[device]]` roster with per-class attribution;
//! * **API shims** — the deprecated `infer` / `infer_with_deadline`
//!   wrappers still route through the [`InferRequest`] builder
//!   unchanged.

use mensa::config::{DeviceClass, DeviceClassSpec, ServerConfig};
use mensa::coordinator::{device, Server};
use mensa::util::rng::Rng;
use std::time::Duration;

const TIMEOUT: Duration = Duration::from_secs(30);

fn artifacts_dir() -> Option<String> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(&format!("{dir}/manifest.toml")).exists() {
        Some(dir.to_string())
    } else {
        eprintln!("SKIP: no artifacts; run `make artifacts`");
        None
    }
}

fn lstm_input(rng: &mut Rng) -> Vec<f32> {
    (0..8 * 128).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect()
}

/// A `joint` request: two dense 128-wide input blocks (one runtime
/// stage each).
fn joint_request(rng: &mut Rng) -> Vec<Vec<f32>> {
    (0..2).map(|_| (0..128).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect()).collect()
}

/// Solo (batch-1, monolithic) outputs from a fresh default server —
/// the bit-exact reference every segmented response must reproduce.
fn solo_outputs(dir: &str, family: &str, requests: &[Vec<Vec<f32>>]) -> Vec<Vec<f32>> {
    let server = Server::start(dir, ServerConfig::default()).expect("solo server");
    let out = requests
        .iter()
        .map(|req| server.infer_blocking(family, req.clone(), TIMEOUT).unwrap().output)
        .collect();
    server.shutdown();
    out
}

/// The segmented serving config shared by the flat tests: family
/// lease on every queue (`reorder_depth = 0`), chunk- and
/// segment-granular sequencing, a small emulated device window so the
/// pipeline's stages genuinely overlap in time.
fn segmented_cfg() -> ServerConfig {
    ServerConfig {
        workers: 4,
        max_batch: 8,
        batch_timeout_us: 10_000,
        work_stealing: true,
        reorder_depth: 0,
        chunk_level: true,
        segment_level: true,
        max_segments: 4,
        device_latency_us: 2_000,
        ..Default::default()
    }
}

/// Flood `requests` through `server`, retrying backpressure, and
/// assert every response is bit-exact against `solo`.
fn flood_bit_exact(
    server: &mensa::coordinator::ServerHandle,
    family: &str,
    requests: &[Vec<Vec<f32>>],
    solo: &[Vec<f32>],
) {
    let rxs: Vec<_> = requests
        .iter()
        .map(|req| loop {
            match server.infer_request(family, req.clone()).send() {
                Ok(rx) => break rx,
                Err(_) => std::thread::sleep(Duration::from_micros(200)),
            }
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv_timeout(TIMEOUT).expect("recv").expect("ok");
        assert_eq!(resp.output, solo[i], "{family} request {i} not bit-exact vs monolithic");
    }
}

fn workers_seen(snap: &mensa::coordinator::metrics::Snapshot, family: &str) -> Vec<usize> {
    snap.workers_by_family
        .iter()
        .find(|(f, _)| f == family)
        .map(|(_, ws)| ws.clone())
        .unwrap_or_default()
}

#[test]
fn segmented_lstm_flood_stays_bit_exact_fifo_and_pipelined() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rng = Rng::new(0x5E91);
    let requests: Vec<Vec<Vec<f32>>> =
        (0..24).map(|_| vec![lstm_input(&mut rng)]).collect();
    let solo = solo_outputs(&dir, "edge_lstm", &requests);

    let server = Server::start(&dir, segmented_cfg()).expect("start");
    flood_bit_exact(&server, "edge_lstm", &requests, &solo);

    let snap = server.metrics();
    assert_eq!(snap.fifo_violations, 0, "segment lanes must preserve strict FIFO");
    assert_eq!(snap.failed, 0);
    assert_eq!(snap.completed, 24);
    // edge_lstm tops out at b4, so the 24-request flood executes as at
    // least 6 chunks — each cut into >= 2 segments (the flat plan is
    // pinned to split by device::tests::flat_plans_pipeline_the_
    // serving_proxies).
    assert!(snap.jobs >= 6, "flood must chunk at the b4 cap, got {} jobs", snap.jobs);
    assert!(
        snap.segments_executed >= 2 * snap.jobs,
        "every chunk must run as >= 2 segments ({} segments over {} jobs)",
        snap.segments_executed,
        snap.jobs
    );
    assert_eq!(
        snap.segment_hops,
        snap.segments_executed - snap.jobs,
        "every non-final segment hands off exactly once"
    );
    let ws = workers_seen(&snap, "edge_lstm");
    assert!(
        ws.len() >= 2,
        "a leased single-family stream must still pipeline across workers, saw {ws:?}"
    );
    server.shutdown();
}

#[test]
fn segmented_dense_family_splits_input_blocks_bit_exact() {
    let Some(dir) = artifacts_dir() else { return };
    // `joint` is the dense multi-stage shape: two input weight blocks
    // give two runtime stages (vs the recurrent timestep axis above).
    let mut rng = Rng::new(0x2013);
    let requests: Vec<Vec<Vec<f32>>> = (0..12).map(|_| joint_request(&mut rng)).collect();
    let solo = solo_outputs(&dir, "joint", &requests);

    let cfg = ServerConfig { max_segments: 2, ..segmented_cfg() };
    let server = Server::start(&dir, cfg).expect("start");
    flood_bit_exact(&server, "joint", &requests, &solo);

    let snap = server.metrics();
    assert_eq!(snap.fifo_violations, 0);
    assert_eq!(snap.failed, 0);
    assert_eq!(snap.completed, 12);
    // The transducer proxy's plan is not pinned here: if it cut, the
    // accounting must hold; serving correctness holds either way.
    if snap.segments_executed > 0 {
        assert!(snap.segments_executed >= 2 * snap.jobs);
        assert_eq!(snap.segment_hops, snap.segments_executed - snap.jobs);
    }
    server.shutdown();
}

#[test]
fn segmented_roster_stays_bit_exact_with_class_attribution() {
    let Some(dir) = artifacts_dir() else { return };
    // Two-class roster calibrated so the slowest class's batch-1
    // window for edge_lstm is ~2 ms (the bench recipe): windows come
    // from the class profiles, not the flat knob.
    let probe = vec![
        DeviceClassSpec { class: DeviceClass::Pascal, workers: 2, latency_scale: 1.0 },
        DeviceClassSpec { class: DeviceClass::Pavlov, workers: 2, latency_scale: 1.0 },
    ];
    let fams = vec!["edge_lstm".to_string()];
    let profiles = device::build_profiles(&probe, &fams, Duration::ZERO);
    let slowest =
        profiles.iter().map(|p| p.base_latency_s("edge_lstm")).fold(0.0f64, f64::max);
    let scale = 2e-3 / slowest.max(1e-12);
    let devices: Vec<DeviceClassSpec> =
        probe.into_iter().map(|s| DeviceClassSpec { latency_scale: scale, ..s }).collect();

    let mut rng = Rng::new(0x4057);
    let requests: Vec<Vec<Vec<f32>>> =
        (0..16).map(|_| vec![lstm_input(&mut rng)]).collect();
    let solo = solo_outputs(&dir, "edge_lstm", &requests);

    let cfg = ServerConfig {
        device_latency_us: 0,
        devices,
        transfer_us: 200,
        spill_after_us: 1_000_000,
        ..segmented_cfg()
    };
    let server = Server::start(&dir, cfg).expect("start");
    flood_bit_exact(&server, "edge_lstm", &requests, &solo);

    let snap = server.metrics();
    assert_eq!(snap.fifo_violations, 0, "cross-class handoffs must preserve FIFO");
    assert_eq!(snap.failed, 0);
    assert_eq!(snap.completed, 16);
    // The roster plan is pinned to split (device unit tests), so the
    // per-segment accounting must engage here too.
    assert!(
        snap.segments_executed >= 2 * snap.jobs,
        "roster pipeline must segment ({} segments over {} jobs)",
        snap.segments_executed,
        snap.jobs
    );
    assert_eq!(snap.segment_hops, snap.segments_executed - snap.jobs);
    // Per-class attribution: every segment lands on a real class. A
    // homogeneous-affinity family may legitimately keep one class, so
    // >= 2 classes is NOT asserted here — the bench's edge_rcnn leg
    // covers the genuine cross-class split (with charged transfers).
    let executed: u64 = snap.jobs_by_device.iter().map(|(_, n)| n).sum();
    assert!(
        !snap.jobs_by_device.is_empty() && executed >= snap.segments_executed,
        "segments must attribute to roster classes, got {:?}",
        snap.jobs_by_device
    );
    server.shutdown();
}

#[test]
#[allow(deprecated)]
fn deprecated_infer_shims_still_route_through_the_builder() {
    let Some(dir) = artifacts_dir() else { return };
    let server = Server::start(&dir, ServerConfig::default()).expect("start");
    let mut rng = Rng::new(0x511A);
    let x = lstm_input(&mut rng);
    let via_builder = server
        .infer_blocking("edge_lstm", vec![x.clone()], TIMEOUT)
        .expect("builder path")
        .output;
    let rx = server.infer("edge_lstm", vec![x.clone()]).expect("infer shim");
    let shim = rx.recv_timeout(TIMEOUT).expect("recv").expect("ok").output;
    assert_eq!(shim, via_builder, "infer shim must match the builder path");
    let rx = server
        .infer_with_deadline("edge_lstm", vec![x.clone()], Some(Duration::from_secs(10)))
        .expect("deadline shim");
    let shim = rx.recv_timeout(TIMEOUT).expect("recv").expect("ok").output;
    assert_eq!(shim, via_builder, "infer_with_deadline shim must match the builder path");
    let rx = server
        .infer_with_deadline("edge_lstm", vec![x], None)
        .expect("no-deadline shim");
    let shim = rx.recv_timeout(TIMEOUT).expect("recv").expect("ok").output;
    assert_eq!(shim, via_builder, "no-deadline shim must match the builder path");
    server.shutdown();
}
