//! Work-stealing executor-pool contracts, end to end through the
//! server:
//!
//! * **bit-exactness** — under a skewed concurrent load (one hot
//!   family flooding, others trickling), every batched response equals
//!   its request's solo output *bit for bit* (same kernels, same
//!   per-sample walk, any misrouting or reordering inside a batch
//!   would mismatch);
//! * **FIFO** — same-family jobs execute in flush order; the batcher
//!   stamps per-family sequence numbers and `Metrics` counts
//!   regressions (`fifo_violations` must stay 0);
//! * **load balance** — a hot family is no longer pinned to one
//!   worker: with stealing enabled, >1 worker observes its jobs
//!   (per-family metrics), while the static baseline keeps it pinned
//!   (exactly 1 worker).

use mensa::config::ServerConfig;
use mensa::coordinator::Server;
use mensa::util::rng::Rng;
use std::time::Duration;

const TIMEOUT: Duration = Duration::from_secs(30);

fn artifacts_dir() -> Option<String> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(&format!("{dir}/manifest.toml")).exists() {
        Some(dir.to_string())
    } else {
        eprintln!("SKIP: no artifacts; run `make artifacts`");
        None
    }
}

fn cnn_input(rng: &mut Rng) -> Vec<f32> {
    (0..32 * 32 * 3).map(|_| rng.range_f64(0.0, 1.0) as f32).collect()
}

fn lstm_input(rng: &mut Rng) -> Vec<f32> {
    (0..8 * 128).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect()
}

#[test]
fn skewed_concurrent_load_stays_bit_exact_and_fifo() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = ServerConfig {
        workers: 4,
        max_batch: 4,
        batch_timeout_us: 10_000,
        work_stealing: true,
        batcher_shards: 2,
        ..Default::default()
    };
    let server = Server::start(&dir, cfg).expect("start");

    // Property-style: several rounds of randomized skewed floods, each
    // case replayable from its seed.
    for round in 0u64..4 {
        let mut rng = Rng::new(0x5EED ^ round);
        // Hot family: 16 edge_cnn requests; background: 4 edge_lstm.
        let hot: Vec<Vec<f32>> = (0..16).map(|_| cnn_input(&mut rng)).collect();
        let cold: Vec<Vec<f32>> = (0..4).map(|_| lstm_input(&mut rng)).collect();

        // Solo baselines (batch of 1 each — sequential).
        let solo_hot: Vec<Vec<f32>> = hot
            .iter()
            .map(|x| {
                server.infer_blocking("edge_cnn", vec![x.clone()], TIMEOUT).unwrap().output
            })
            .collect();
        let solo_cold: Vec<Vec<f32>> = cold
            .iter()
            .map(|x| {
                server.infer_blocking("edge_lstm", vec![x.clone()], TIMEOUT).unwrap().output
            })
            .collect();

        // Concurrent skewed flood: interleave a cold request after
        // every 4th hot one.
        let mut rxs = Vec::new();
        for (i, x) in hot.iter().enumerate() {
            rxs.push((
                "edge_cnn",
                i,
                server.infer_request("edge_cnn", vec![x.clone()]).send().unwrap(),
            ));
            if i % 4 == 3 {
                let c = i / 4;
                rxs.push((
                    "edge_lstm",
                    c,
                    server.infer_request("edge_lstm", vec![cold[c].clone()]).send().unwrap(),
                ));
            }
        }
        let mut batched = 0;
        for (family, i, rx) in rxs {
            let resp = rx.recv_timeout(TIMEOUT).expect("recv").expect("ok");
            let solo = if family == "edge_cnn" { &solo_hot[i] } else { &solo_cold[i] };
            assert_eq!(
                &resp.output, solo,
                "round {round}: {family} request {i} not bit-exact vs solo"
            );
            if resp.batch_size > 1 {
                batched += 1;
            }
        }
        assert!(batched >= 4, "round {round}: flood did not coalesce ({batched} batched)");
    }

    let snap = server.metrics();
    assert_eq!(snap.fifo_violations, 0, "same-family jobs must execute in flush order");
    assert_eq!(snap.failed, 0);
    server.shutdown();
}

#[test]
fn hot_family_migrates_across_workers_when_stealing() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = ServerConfig {
        workers: 4,
        max_batch: 8,
        batch_timeout_us: 500,
        work_stealing: true,
        ..Default::default()
    };
    let server = Server::start(&dir, cfg).expect("start");
    let mut rng = Rng::new(7);
    // Sequential blocking requests: each flush finds the whole pool
    // idle, so the idle-queue rotation must spread the single hot
    // family across workers (the anti-pinning regression test).
    for _ in 0..16 {
        let x = cnn_input(&mut rng);
        server.infer_blocking("edge_cnn", vec![x], TIMEOUT).expect("inference");
        std::thread::sleep(Duration::from_millis(2));
    }
    let snap = server.metrics();
    let workers_seen = snap
        .workers_by_family
        .iter()
        .find(|(f, _)| f == "edge_cnn")
        .map(|(_, ws)| ws.clone())
        .unwrap_or_default();
    assert!(
        workers_seen.len() > 1,
        "hot family stayed pinned to workers {workers_seen:?} despite stealing"
    );
    assert_eq!(snap.fifo_violations, 0);
    server.shutdown();
}

#[test]
fn static_baseline_pins_hot_family_to_one_worker() {
    let Some(dir) = artifacts_dir() else { return };
    let cfg = ServerConfig {
        workers: 4,
        max_batch: 8,
        batch_timeout_us: 500,
        work_stealing: false,
        ..Default::default()
    };
    let server = Server::start(&dir, cfg).expect("start");
    let mut rng = Rng::new(11);
    for _ in 0..8 {
        let x = cnn_input(&mut rng);
        server.infer_blocking("edge_cnn", vec![x], TIMEOUT).expect("inference");
    }
    let snap = server.metrics();
    let workers_seen = snap
        .workers_by_family
        .iter()
        .find(|(f, _)| f == "edge_cnn")
        .map(|(_, ws)| ws.clone())
        .unwrap_or_default();
    assert_eq!(
        workers_seen.len(),
        1,
        "static routing must keep a family on exactly one worker, saw {workers_seen:?}"
    );
    server.shutdown();
}

#[test]
fn oversized_jobs_chunk_in_order_under_stealing() {
    // edge_lstm tops out at b4; an 8-request flood must chunk without
    // reordering or failures on the stealing pool.
    let Some(dir) = artifacts_dir() else { return };
    let cfg = ServerConfig {
        workers: 4,
        max_batch: 8,
        batch_timeout_us: 50_000,
        work_stealing: true,
        ..Default::default()
    };
    let server = Server::start(&dir, cfg).expect("start");
    let mut rng = Rng::new(23);
    let inputs: Vec<Vec<f32>> = (0..8).map(|_| lstm_input(&mut rng)).collect();
    let solo: Vec<Vec<f32>> = inputs
        .iter()
        .map(|x| server.infer_blocking("edge_lstm", vec![x.clone()], TIMEOUT).unwrap().output)
        .collect();
    let rxs: Vec<_> = inputs
        .iter()
        .map(|x| server.infer_request("edge_lstm", vec![x.clone()]).send().expect("submit"))
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv_timeout(TIMEOUT).expect("recv").expect("chunked execution");
        assert!(resp.batch_size <= 4, "chunk exceeds largest variant");
        assert_eq!(&resp.output, &solo[i], "request {i} bit-exact through chunking");
    }
    let snap = server.metrics();
    assert_eq!(snap.failed, 0);
    assert_eq!(snap.fifo_violations, 0);
    server.shutdown();
}
