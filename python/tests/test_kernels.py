"""Kernel-vs-oracle correctness: the core L1 signal.

Hypothesis sweeps shapes/dtypes/tilings for each Pallas kernel against
the pure-jnp references in `compile.kernels.ref`.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import jacquard_mvm, lstm_cell, lstm_layer, pascal_matmul
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)

# Keep hypothesis deadlines off: interpret-mode pallas is slow per call.
SETTINGS = dict(max_examples=20, deadline=None)

dims = st.sampled_from([8, 16, 24, 32, 64])
tile = st.sampled_from([8, 16, 32, 128])


def _rand(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, dtype=jnp.float32)
    return x.astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-4, atol=1e-5)


class TestPascalMatmul:
    @settings(**SETTINGS)
    @given(m=dims, k=dims, n=dims, bm=tile, bn=tile, bk=tile)
    def test_matches_reference_f32(self, m, k, n, bm, bn, bk):
        # Only exercise tilings that divide the shape (the kernel's
        # contract); others are covered by the error tests.
        bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
        if m % bm or n % bn or k % bk:
            return
        x = _rand(1, (m, k), jnp.float32)
        w = _rand(2, (k, n), jnp.float32)
        got = pascal_matmul(x, w, bm=bm, bn=bn, bk=bk)
        np.testing.assert_allclose(got, ref.matmul_ref(x, w), **_tol(jnp.float32))

    @settings(**SETTINGS)
    @given(m=dims, k=dims, n=dims)
    def test_matches_reference_bf16(self, m, k, n):
        x = _rand(3, (m, k), jnp.bfloat16)
        w = _rand(4, (k, n), jnp.bfloat16)
        got = pascal_matmul(x, w)
        np.testing.assert_allclose(
            got.astype(jnp.float32),
            ref.matmul_ref(x, w).astype(jnp.float32),
            **_tol(jnp.bfloat16),
        )

    def test_default_tiles_clamp_to_shape(self):
        x = _rand(5, (16, 24), jnp.float32)
        w = _rand(6, (24, 8), jnp.float32)
        got = pascal_matmul(x, w)  # bm=128 etc. clamp to 16/8/24
        np.testing.assert_allclose(got, ref.matmul_ref(x, w), **_tol(jnp.float32))

    def test_rejects_mismatched_inner_dims(self):
        x = jnp.zeros((8, 16))
        w = jnp.zeros((8, 8))
        with pytest.raises(ValueError, match="inner dims"):
            pascal_matmul(x, w)

    def test_rejects_nondividing_tiles(self):
        x = jnp.zeros((12, 8))
        w = jnp.zeros((8, 8))
        with pytest.raises(ValueError, match="divide"):
            pascal_matmul(x, w, bm=8)

    def test_large_k_accumulation(self):
        # Many K tiles: the temporal-reduction loop is really exercised.
        x = _rand(7, (16, 512), jnp.float32)
        w = _rand(8, (512, 16), jnp.float32)
        got = pascal_matmul(x, w, bk=32)
        np.testing.assert_allclose(got, ref.matmul_ref(x, w), rtol=1e-3, atol=1e-3)


class TestJacquardMvm:
    @settings(**SETTINGS)
    @given(k=dims, n=dims, bn=tile, bk=tile)
    def test_matches_reference(self, k, n, bn, bk):
        bn, bk = min(bn, n), min(bk, k)
        if n % bn or k % bk:
            return
        x = _rand(9, (k,), jnp.float32)
        w = _rand(10, (k, n), jnp.float32)
        got = jacquard_mvm(x, w, bn=bn, bk=bk)
        np.testing.assert_allclose(got, ref.mvm_ref(x, w), **_tol(jnp.float32))

    def test_partial_sum_reduction_over_many_k_tiles(self):
        x = _rand(11, (1024,), jnp.float32)
        w = _rand(12, (1024, 32), jnp.float32)
        got = jacquard_mvm(x, w, bk=64)
        np.testing.assert_allclose(got, ref.mvm_ref(x, w), rtol=1e-3, atol=1e-3)

    def test_rejects_mismatch(self):
        with pytest.raises(ValueError, match="inner dims"):
            jacquard_mvm(jnp.zeros((8,)), jnp.zeros((16, 8)))


class TestPavlovLstm:
    @settings(**SETTINGS)
    @given(b=st.sampled_from([1, 2, 4]), d=st.sampled_from([8, 16, 32]),
           h=st.sampled_from([8, 16, 32]))
    def test_cell_matches_reference(self, b, d, h):
        x = _rand(13, (b, d), jnp.float32)
        hh = _rand(14, (b, h), jnp.float32)
        c = _rand(15, (b, h), jnp.float32)
        w = _rand(16, (d + h, 4 * h), jnp.float32) * 0.2
        bias = _rand(17, (4 * h,), jnp.float32) * 0.1
        h_new, c_new = lstm_cell(x, hh, c, w, bias)
        h_ref, c_ref = ref.lstm_cell_ref(x, hh, c, w, bias)
        np.testing.assert_allclose(h_new, h_ref, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(c_new, c_ref, rtol=1e-4, atol=1e-5)

    @settings(max_examples=8, deadline=None)
    @given(t=st.sampled_from([1, 2, 5, 8]), b=st.sampled_from([1, 3]))
    def test_layer_matches_reference_over_time(self, t, b):
        d = h = 16
        xs = _rand(18, (t, b, d), jnp.float32)
        w = _rand(19, (d + h, 4 * h), jnp.float32) * 0.2
        bias = jnp.zeros((4 * h,), jnp.float32)
        h0 = jnp.zeros((b, h), jnp.float32)
        c0 = jnp.zeros((b, h), jnp.float32)
        hs, (h_t, c_t) = lstm_layer(xs, h0, c0, w, bias)
        hs_ref, (h_ref, c_ref) = ref.lstm_layer_ref(xs, h0, c0, w, bias)
        np.testing.assert_allclose(hs, hs_ref, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(h_t, h_ref, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(c_t, c_ref, rtol=1e-4, atol=1e-5)

    def test_state_propagates_between_steps(self):
        # A zero-input sequence must still evolve state via biases.
        t, b, d, h = 3, 1, 8, 8
        xs = jnp.zeros((t, b, d), jnp.float32)
        w = _rand(20, (d + h, 4 * h), jnp.float32) * 0.3
        bias = jnp.ones((4 * h,), jnp.float32) * 0.5
        hs, _ = lstm_layer(xs, jnp.zeros((b, h)), jnp.zeros((b, h)), w, bias)
        # Hidden state changes step to step (saturating, not constant).
        assert not np.allclose(hs[0], hs[1])
        assert not np.allclose(hs[1], hs[2])

    def test_forget_gate_saturation_preserves_cell(self):
        # With a hugely positive forget bias and zero input/modulation,
        # the cell state must be (approximately) carried through.
        b, d, h = 1, 8, 8
        w = jnp.zeros((d + h, 4 * h), jnp.float32)
        bias = jnp.concatenate(
            [
                jnp.full((h,), -20.0),  # input gate closed
                jnp.zeros((h,)),        # modulation irrelevant
                jnp.full((h,), 20.0),   # forget gate open (keep)
                jnp.full((h,), -20.0),  # output gate closed
            ]
        )
        c0 = jnp.linspace(-1.0, 1.0, h).reshape(1, h)
        _, c1 = lstm_cell(jnp.zeros((b, d)), jnp.zeros((b, h)), c0, w, bias)
        np.testing.assert_allclose(c1, c0, rtol=1e-4, atol=1e-5)
