"""L2 model tests: shapes, determinism, and kernel-vs-jnp parity at the
whole-model level."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


class TestEdgeCnn:
    @pytest.mark.parametrize("b", [1, 4, 8])
    def test_output_shape(self, b):
        x = jnp.zeros((b, 32, 32, 3), jnp.float32)
        (out,) = model.cnn_fn()(x)
        assert out.shape == (b, model.NUM_CLASSES)

    def test_deterministic_params(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
        (a,) = model.cnn_fn()(x)
        (b,) = model.cnn_fn()(x)
        np.testing.assert_array_equal(a, b)

    def test_conv_matches_lax_conv(self):
        # The im2col + Pascal path must equal XLA's native convolution.
        key = jax.random.PRNGKey(7)
        x = jax.random.normal(key, (2, 16, 16, 8))
        w = jax.random.normal(key, (3, 3, 8, 16)) * 0.1
        got = model.conv2d(x, w, stride=1)
        want = jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_strided_conv_matches_lax_conv(self):
        key = jax.random.PRNGKey(8)
        x = jax.random.normal(key, (1, 32, 32, 3))
        w = jax.random.normal(key, (3, 3, 3, 32)) * 0.1
        got = model.conv2d(x, w, stride=2)
        want = jax.lax.conv_general_dilated(
            x, w, (2, 2), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_batch_consistency(self):
        # Running a batch must equal running items individually: the
        # dynamic batcher on the Rust side depends on this.
        x = jax.random.normal(jax.random.PRNGKey(2), (4, 32, 32, 3))
        (batched,) = model.cnn_fn()(x)
        singles = jnp.concatenate([model.cnn_fn()(x[i : i + 1])[0] for i in range(4)])
        np.testing.assert_allclose(batched, singles, rtol=1e-4, atol=1e-4)


class TestEdgeLstm:
    @pytest.mark.parametrize("b", [1, 4])
    def test_output_shape(self, b):
        xs = jnp.zeros((8, b, model.LSTM_D), jnp.float32)
        (out,) = model.lstm_fn()(xs)
        assert out.shape == (b, model.LSTM_VOCAB)

    def test_matches_pure_jnp_reference(self):
        params = model.make_lstm_params()
        xs = jax.random.normal(jax.random.PRNGKey(3), (4, 2, model.LSTM_D)) * 0.5
        (got,) = model.lstm_fn()(xs)
        # Reference: same math with the ref cell.
        h = xs
        b = xs.shape[1]
        for layer in params["layers"]:
            h0 = jnp.zeros((b, model.LSTM_H))
            c0 = jnp.zeros((b, model.LSTM_H))
            h, (h_t, _) = ref.lstm_layer_ref(h, h0, c0, layer["w"], layer["b"])
        want = h_t @ params["proj"]
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    def test_sequence_order_matters(self):
        xs = jax.random.normal(jax.random.PRNGKey(4), (8, 1, model.LSTM_D))
        (fwd,) = model.lstm_fn()(xs)
        (rev,) = model.lstm_fn()(xs[::-1])
        assert not np.allclose(fwd, rev), "LSTM must be order-sensitive"


class TestTransducerJoint:
    @pytest.mark.parametrize("b", [1, 4])
    def test_output_shape(self, b):
        enc = jnp.zeros((b, model.JOINT_ENC))
        pred = jnp.zeros((b, model.JOINT_PRED))
        (out,) = model.joint_fn()(enc, pred)
        assert out.shape == (b, model.JOINT_VOCAB)

    def test_batch1_jacquard_path_matches_batched_pascal_path(self):
        # The two kernel paths must agree: a batch-1 request answered by
        # the Jacquard MVM equals the same row through the Pascal path.
        key = jax.random.PRNGKey(5)
        enc = jax.random.normal(key, (4, model.JOINT_ENC))
        pred = jax.random.normal(key, (4, model.JOINT_PRED))
        (batched,) = model.joint_fn()(enc, pred)
        for i in range(4):
            (single,) = model.joint_fn()(enc[i : i + 1], pred[i : i + 1])
            np.testing.assert_allclose(single[0], batched[i], rtol=1e-3, atol=1e-3)
