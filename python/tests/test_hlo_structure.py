"""Structural checks on the lowered HLO (L2 optimization properties) and
the AOT manifest.

These tests pin the properties the Rust side and the §Perf analysis
rely on: gate fusion (one dot per LSTM step, not eight), scan-based
weight hoisting (model size independent of T in the dot count), and
manifest/artifact integrity.
"""

import re

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model


def hlo_for(fn, *specs):
    return aot.to_hlo_text(jax.jit(fn).lower(*specs))


def count_ops(hlo: str, op: str) -> int:
    return len(re.findall(rf"= \S+ {op}\(", hlo))


@pytest.fixture(scope="module")
def lstm_hlo():
    spec = jax.ShapeDtypeStruct((8, 1, model.LSTM_D), jnp.float32)
    return hlo_for(model.lstm_fn(), spec)


class TestLstmFusion:
    def test_one_dot_per_layer_step_not_eight(self, lstm_hlo):
        # Pavlov gate batching: each LSTM layer contributes ONE fused
        # dot inside the scan body (plus the projection). The naive
        # formulation would emit 8 dots per layer (2 MVMs x 4 gates).
        dots = count_ops(lstm_hlo, "dot")
        # 2 scan bodies (one per layer) x 1 dot + 1 projection dot; XLA
        # may keep a couple of helper dots, but 8-per-gate would blow
        # far past this bound.
        assert dots <= model.LSTM_LAYERS + 2, f"{dots} dots — gates not fused?"

    def test_scan_keeps_dot_count_independent_of_t(self):
        spec_short = jax.ShapeDtypeStruct((2, 1, model.LSTM_D), jnp.float32)
        spec_long = jax.ShapeDtypeStruct((16, 1, model.LSTM_D), jnp.float32)
        d_short = count_ops(hlo_for(model.lstm_fn(), spec_short), "dot")
        d_long = count_ops(hlo_for(model.lstm_fn(), spec_long), "dot")
        assert d_short == d_long, "unrolled over time — weights refetch per step"

    def test_uses_while_loop_for_sequence(self, lstm_hlo):
        assert "while(" in lstm_hlo, "scan should lower to an HLO while loop"


class TestCnnHlo:
    def test_dot_count_matches_kernelized_layers(self):
        spec = jax.ShapeDtypeStruct((1, 32, 32, 3), jnp.float32)
        hlo = hlo_for(model.cnn_fn(), spec)
        # stem + pw1 + pw2 + fc go through pascal_matmul -> 4 dots;
        # depthwise layers lower to convolutions (2), plus the stem's
        # im2col patch extraction lowers to one identity convolution.
        assert count_ops(hlo, "dot") == 4
        assert count_ops(hlo, "convolution") == 3

    def test_parameters_are_baked_constants(self):
        spec = jax.ShapeDtypeStruct((1, 32, 32, 3), jnp.float32)
        hlo = hlo_for(model.cnn_fn(), spec)
        # Single entry parameter: the input image. Weights must appear
        # as constants, not runtime parameters (check the entry layout,
        # not subcomputations, which have their own parameter(N)s).
        layout = re.search(r"entry_computation_layout=\{\(([^)]*)\)", hlo).group(1)
        n_inputs = len([s for s in layout.split("f32[") if s.strip()]) - 0
        assert layout.count("f32[") == 1, f"unexpected entry inputs: {layout}"
        assert n_inputs >= 1


class TestManifest:
    @pytest.fixture(scope="class")
    def exported(self, tmp_path_factory):
        out = tmp_path_factory.mktemp("artifacts")
        text = aot.export_all(str(out))
        return out, text

    def test_every_artifact_listed_and_present(self, exported):
        out, text = exported
        names = re.findall(r'name = "([^"]+)"', text)
        assert len(names) == len(aot.artifact_list())
        for fname in re.findall(r'file = "([^"]+)"', text):
            assert (out / fname).exists(), f"{fname} missing"

    def test_manifest_shapes_match_specs(self, exported):
        _, text = exported
        assert 'input0_shape = "1x32x32x3"' in text
        assert f'input0_shape = "{aot.LSTM_T}x1x{model.LSTM_D}"' in text
        assert 'output_shape = "1x16"' in text

    def test_hlo_text_is_parseable_entry_computation(self, exported):
        out, _ = exported
        for f in out.glob("*.hlo.txt"):
            head = f.read_text()[:200]
            assert "HloModule" in head, f"{f.name}: not HLO text"

    def test_export_is_deterministic(self, exported, tmp_path):
        _, first = exported
        second = aot.export_all(str(tmp_path))
        # Identical manifests (incl. sha256 digests) run-to-run.
        assert first == second
