"""pytest setup: make `compile` importable when run from python/."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
