"""Pascal dataflow as a Pallas kernel: output-stationary tiled matmul.

Mapping of §5.3's silicon mechanisms onto TPU/Pallas:

* *Temporal reduction in PE registers* → each ``(m, n)`` grid cell owns
  one ``(bm, bn)`` output tile that stays resident in VMEM while the K
  grid dimension iterates over reduction tiles; partial sums accumulate
  in place and never leave the core (the paper's "avoid spatial
  reduction for output activations").
* *Spatial multicast of parameters* → the ``(bk, bn)`` weight tile is a
  single VMEM-resident operand reused by every row of the activation
  tile in one MXU op.
* *HBM↔VMEM schedule* → the ``BlockSpec`` index maps express exactly
  which tile each grid step touches — the job §5.3's dataflow diagram
  does with PE timing. Pallas double-buffers the streamed tiles.

Block sizes default to MXU-aligned 128 and must divide the operand
shapes (checked); accumulation is f32 regardless of operand dtype.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref):
    """One (m, n, k) grid step: accumulate ``x_tile @ w_tile``."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        # First reduction step: claim the output tile.
        o_ref[...] = jnp.zeros_like(o_ref)

    # MXU op: the weight tile is spatially multicast across every
    # activation row; the output tile is temporally reduced in place.
    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def pascal_matmul(x, w, *, bm: int = 128, bn: int = 128, bk: int = 128):
    """Compute ``x @ w`` with the Pascal output-stationary dataflow.

    Args:
        x: ``[M, K]`` activations.
        w: ``[K, N]`` parameters.
        bm: output-tile rows (clamped to M; must then divide it).
        bn: output-tile cols (clamped to N; must then divide it).
        bk: reduction-tile depth (clamped to K; must then divide it).

    Returns:
        ``[M, N] = x @ w`` in ``x``'s dtype.
    """
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"inner dims mismatch: {k} vs {k2}")
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    if m % bm or n % bn or k % bk:
        raise ValueError(f"tiles ({bm},{bn},{bk}) must divide shape ({m},{n},{k})")
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, w)
