"""Jacquard dataflow as a Pallas kernel: weight-stationary MVM with
K-tiled partial-sum reduction.

Mapping of §5.5's silicon mechanisms onto TPU/Pallas:

* *Temporal multicast of parameters* → each ``(bk, bn)`` weight tile is
  loaded into VMEM once per grid step and reused across the whole input
  vector chunk (register residency analogue). Every weight byte crosses
  HBM exactly once.
* *Spatial reduction via the NoC gather* → the K grid dimension produces
  per-tile partial sums that accumulate into the VMEM-resident output
  block — the interconnect gather becomes the accumulator loop.
* *Spatial multicast of input activations* → the ``(1, bk)`` activation
  chunk is broadcast against all ``bn`` weight columns in one op.

The input is a single vector (M=1, the Family-3/4 MVM shape); batched
callers stack vectors and use :mod:`.pascal_matmul` instead.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref):
    """One (n, k) grid step: partial sum of a weight row-tile."""
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Spatial multicast of the activation chunk against bn columns,
    # partial sums gathered into the output block (spatial reduction).
    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bn", "bk"))
def jacquard_mvm(x, w, *, bn: int = 128, bk: int = 128):
    """Compute ``x @ w`` for a vector ``x`` with the Jacquard dataflow.

    Args:
        x: ``[K]`` input activation vector.
        w: ``[K, N]`` parameter matrix.
        bn: output tile width (clamped to N; must then divide it).
        bk: reduction tile depth (clamped to K; must then divide it).

    Returns:
        ``[N] = x @ w`` in ``x``'s dtype.
    """
    (k,) = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"inner dims mismatch: {k} vs {k2}")
    bn, bk = min(bn, n), min(bk, k)
    if n % bn or k % bk:
        raise ValueError(f"tiles ({bn},{bk}) must divide shape ({n},{k})")
    x2 = x.reshape(1, k)
    grid = (n // bn, k // bk)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bk), lambda j, kk: (0, kk)),
            pl.BlockSpec((bk, bn), lambda j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda j, kk: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, n), x.dtype),
        interpret=True,
    )(x2, w)
    return out.reshape(n)
