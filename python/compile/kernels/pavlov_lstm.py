"""Pavlov dataflow as Pallas kernels: gate-batched LSTM cell.

Mapping of §5.4's silicon mechanisms onto TPU/Pallas:

* *Gate batching* → the four gates' input and hidden weight matrices are
  fused into one ``[D+H, 4H]`` operand, so the MXU executes **one**
  large matmul per timestep instead of eight serialized gate MVMs (the
  Edge TPU's "treats each gate as two FC layers" pathology, §3.2.1).
* *Weight residency* → the fused weight block is one VMEM-resident
  operand reused across the K loop; across the sequence scan, XLA hoists
  the weights so each byte streams from HBM once per step batch — the
  register-residency analogue of "fetch each element of W only once".
* *Temporal reduction of outputs* → the gate pre-activations accumulate
  in the output tile across K grid steps (same mechanism as Pascal's
  accumulator, reused here for the 4H-wide fused output).
* The elementwise cell update (sigmoid/tanh products) is a separate VPU
  kernel — it has no MXU work and its fusion into the matmul would only
  constrain the schedule.

Gate ordering in the fused ``4H`` axis: ``i, g, f, o`` (input, input
modulation, forget, output) — matching §2's gate list.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .pascal_matmul import pascal_matmul


def _update_kernel(gates_ref, c_ref, h_out_ref, c_out_ref, *, hidden: int):
    """Elementwise LSTM cell update: (i, g, f, o) + c -> (h', c')."""
    gates = gates_ref[...]
    i = jax.nn.sigmoid(gates[:, 0 * hidden : 1 * hidden])
    g = jnp.tanh(gates[:, 1 * hidden : 2 * hidden])
    f = jax.nn.sigmoid(gates[:, 2 * hidden : 3 * hidden])
    o = jax.nn.sigmoid(gates[:, 3 * hidden : 4 * hidden])
    c_new = f * c_ref[...] + i * g
    c_out_ref[...] = c_new
    h_out_ref[...] = o * jnp.tanh(c_new)


def _cell_update(gates, c):
    """Run the VPU update kernel over a full ``[B, 4H]`` gate block."""
    b, four_h = gates.shape
    hidden = four_h // 4
    h_new, c_new = pl.pallas_call(
        functools.partial(_update_kernel, hidden=hidden),
        out_shape=(
            jax.ShapeDtypeStruct((b, hidden), gates.dtype),
            jax.ShapeDtypeStruct((b, hidden), gates.dtype),
        ),
        interpret=True,
    )(gates, c)
    return h_new, c_new


def lstm_cell(x, h, c, w_fused, b_fused, *, block: int = 128):
    """One LSTM step with the Pavlov gate-batched dataflow.

    Args:
        x: ``[B, D]`` step input.
        h: ``[B, H]`` previous hidden state.
        c: ``[B, H]`` previous cell state.
        w_fused: ``[D + H, 4H]`` fused gate weights (i|g|f|o blocks).
        b_fused: ``[4H]`` fused biases.
        block: matmul tile size.

    Returns:
        ``(h_new, c_new)``, each ``[B, H]``.
    """
    xh = jnp.concatenate([x, h], axis=1)
    # ONE fused MXU matmul for all four gates (the dataflow's headline).
    gates = pascal_matmul(xh, w_fused, bm=block, bn=block, bk=block) + b_fused
    return _cell_update(gates, c)


def lstm_layer(xs, h0, c0, w_fused, b_fused, *, block: int = 128):
    """Run a full LSTM layer over a sequence.

    The scan carries ``(h, c)``; weights are loop-invariant, so the
    lowered HLO fetches them once for the whole sequence — exactly the
    "fetch each element of W only once per layer" property of §5.4.

    Args:
        xs: ``[T, B, D]`` input sequence.
        h0: ``[B, H]`` initial hidden state.
        c0: ``[B, H]`` initial cell state.
        w_fused: ``[D + H, 4H]`` fused gate weights.
        b_fused: ``[4H]`` fused biases.
        block: matmul tile size.

    Returns:
        ``(hs, (h_T, c_T))`` where ``hs`` is ``[T, B, H]``.
    """

    def step(carry, x_t):
        h, c = carry
        h_new, c_new = lstm_cell(x_t, h, c, w_fused, b_fused, block=block)
        return (h_new, c_new), h_new

    (h_t, c_t), hs = jax.lax.scan(step, (h0, c0), xs)
    return hs, (h_t, c_t)
