"""Layer-1 Pallas kernels implementing the Mensa-G dataflows.

Each kernel re-thinks one of the paper's silicon dataflows (§5.3-§5.5)
for TPU idioms (see DESIGN.md §Hardware-Adaptation):

* :mod:`.pascal_matmul` — output-stationary tiled matmul: each grid cell
  owns an output tile accumulated in VMEM across the K grid dimension
  (the PE-register temporal reduction), with the weight tile broadcast
  across the whole output tile (spatial multicast).
* :mod:`.pavlov_lstm` — gate-batched LSTM cell: the four gates' weights
  are fused into one ``[D+H, 4H]`` operand so the MXU sees a single
  large matmul per step and each weight byte is touched once per step
  rather than once per gate-MVM.
* :mod:`.jacquard_mvm` — weight-stationary MVM with K-tiled partial sums
  accumulated in the output ref (the NoC spatial-reduction analogue).

All kernels run under ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom calls, so correctness is validated through the
interpreter and TPU performance is *estimated* from block shapes
(EXPERIMENTS.md §Perf).
"""

from .jacquard_mvm import jacquard_mvm
from .pascal_matmul import pascal_matmul
from .pavlov_lstm import lstm_cell, lstm_layer

__all__ = ["pascal_matmul", "lstm_cell", "lstm_layer", "jacquard_mvm"]
