"""Pure-jnp oracles for every Pallas kernel.

These are the correctness ground truth: no Pallas, no tiling — just the
textbook math. pytest (`python/tests/test_kernels.py`) sweeps shapes and
dtypes with hypothesis and asserts the kernels match these within
accumulation tolerance.
"""

import jax
import jax.numpy as jnp


def matmul_ref(x, w):
    """``[M, K] @ [K, N]`` reference."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def mvm_ref(x, w):
    """``[K] @ [K, N]`` reference."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def lstm_cell_ref(x, h, c, w_fused, b_fused):
    """One LSTM step, gates fused as (i|g|f|o) like the kernel."""
    hidden = h.shape[1]
    gates = jnp.concatenate([x, h], axis=1) @ w_fused + b_fused
    i = jax.nn.sigmoid(gates[:, 0 * hidden : 1 * hidden])
    g = jnp.tanh(gates[:, 1 * hidden : 2 * hidden])
    f = jax.nn.sigmoid(gates[:, 2 * hidden : 3 * hidden])
    o = jax.nn.sigmoid(gates[:, 3 * hidden : 4 * hidden])
    c_new = f * c + i * g
    h_new = o * jnp.tanh(c_new)
    return h_new, c_new


def lstm_layer_ref(xs, h0, c0, w_fused, b_fused):
    """Full-sequence LSTM reference (python loop; oracle only)."""
    h, c = h0, c0
    hs = []
    for t in range(xs.shape[0]):
        h, c = lstm_cell_ref(xs[t], h, c, w_fused, b_fused)
        hs.append(h)
    return jnp.stack(hs), (h, c)


def split_gate_weights(w_x_gates, w_h_gates):
    """Fuse per-gate ``W_x``/``W_h`` lists into the kernel's layout.

    Args:
        w_x_gates: list of four ``[D, H]`` matrices (i, g, f, o).
        w_h_gates: list of four ``[H, H]`` matrices (i, g, f, o).

    Returns:
        ``[D + H, 4H]`` fused operand.
    """
    w_x = jnp.concatenate(list(w_x_gates), axis=1)  # [D, 4H]
    w_h = jnp.concatenate(list(w_h_gates), axis=1)  # [H, 4H]
    return jnp.concatenate([w_x, w_h], axis=0)
