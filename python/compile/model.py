"""Layer-2 JAX model definitions built on the Layer-1 kernels.

Three representative edge models mirroring the zoo's classes (the full
24-model zoo lives in Rust for the simulator; these are the *executable*
models whose AOT artifacts the Rust runtime serves):

* :func:`edge_cnn` — MobileNet-style CNN: standard-conv stem, separable
  (depthwise + pointwise) blocks, global pool, FC classifier. All
  matmul-shaped compute routes through :func:`kernels.pascal_matmul`.
* :func:`edge_lstm` — stacked LSTM with Pavlov gate batching: one fused
  MXU matmul per step per layer (:func:`kernels.lstm_layer`).
* :func:`transducer_joint` — RNN-T joint: two FC layers over the
  concatenated encoder/prediction outputs, the Family-3 MVM shape
  (:func:`kernels.jacquard_mvm` for batch-1, Pascal for batched).

Parameters are generated deterministically (fixed PRNG seed) and baked
into the lowered computation as constants: the serving path feeds
inputs only, exactly like a deployed quantized edge model.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import jacquard_mvm, lstm_layer, pascal_matmul
from .kernels.ref import split_gate_weights

# ----------------------------------------------------------------------
# Parameter initialization (deterministic)
# ----------------------------------------------------------------------


def _init(key, shape, scale=None):
    fan_in = shape[0] if len(shape) == 1 else int(jnp.prod(jnp.array(shape[:-1])))
    scale = scale if scale is not None else (1.0 / max(fan_in, 1)) ** 0.5
    return jax.random.normal(key, shape, dtype=jnp.float32) * scale


# ----------------------------------------------------------------------
# CNN building blocks
# ----------------------------------------------------------------------


def conv2d(x, w, *, stride=1):
    """Standard convolution via im2col + the Pascal matmul kernel.

    Args:
        x: ``[B, H, W, C]`` activations.
        w: ``[kh, kw, C, O]`` filters.
        stride: spatial stride.

    Returns:
        ``[B, H/stride, W/stride, O]``.
    """
    kh, kw, c, o = w.shape
    b = x.shape[0]
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # [B, H', W', C*kh*kw] — feature dim is channel-major (C, kh, kw)
    oh, ow = patches.shape[1], patches.shape[2]
    mat = patches.reshape(b * oh * ow, c * kh * kw)
    # Match the patch layout: (kh, kw, C, O) -> (C, kh, kw, O).
    w_mat = jnp.transpose(w, (2, 0, 1, 3)).reshape(c * kh * kw, o)
    out = pascal_matmul(mat, w_mat)
    return out.reshape(b, oh, ow, o)


def depthwise2d(x, w):
    """Depthwise 3x3 convolution (single channel per filter — the
    no-input-reuse Family-5 shape; VPU work, not MXU)."""
    return jax.lax.conv_general_dilated(
        x,
        w,  # [kh, kw, 1, C] with feature_group_count=C
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=x.shape[-1],
    )


def pointwise(x, w):
    """Pointwise (1x1) convolution as a Pascal matmul."""
    b, h, wd, c = x.shape
    o = w.shape[1]
    out = pascal_matmul(x.reshape(b * h * wd, c), w)
    return out.reshape(b, h, wd, o)


# ----------------------------------------------------------------------
# Models
# ----------------------------------------------------------------------

NUM_CLASSES = 16


def make_cnn_params(key=None):
    """Deterministic EdgeCNN parameters."""
    key = key if key is not None else jax.random.PRNGKey(0xEDCE)
    ks = jax.random.split(key, 8)
    return {
        "stem": _init(ks[0], (3, 3, 3, 32)),
        "dw1": _init(ks[1], (3, 3, 1, 32)),
        "pw1": _init(ks[2], (32, 64)),
        "dw2": _init(ks[3], (3, 3, 1, 64)),
        "pw2": _init(ks[4], (64, 128)),
        "fc": _init(ks[5], (128, NUM_CLASSES)),
        "fc_b": jnp.zeros((NUM_CLASSES,), jnp.float32),
    }


def edge_cnn(x, params):
    """MobileNet-style classifier over ``[B, 32, 32, 3]`` inputs."""
    h = jax.nn.relu(conv2d(x, params["stem"], stride=2))  # 16x16x32
    h = jax.nn.relu(depthwise2d(h, params["dw1"]))
    h = jax.nn.relu(pointwise(h, params["pw1"]))  # 16x16x64
    h = jax.lax.reduce_window(
        h, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )  # 8x8x64
    h = jax.nn.relu(depthwise2d(h, params["dw2"]))
    h = jax.nn.relu(pointwise(h, params["pw2"]))  # 8x8x128
    h = jnp.mean(h, axis=(1, 2))  # global average pool -> [B, 128]
    return pascal_matmul(h, params["fc"]) + params["fc_b"]


LSTM_D = 128
LSTM_H = 128
LSTM_LAYERS = 2
LSTM_VOCAB = 256


def make_lstm_params(key=None):
    """Deterministic EdgeLSTM parameters (fused-gate layout)."""
    key = key if key is not None else jax.random.PRNGKey(0x15F3)
    params = {"layers": []}
    for layer in range(LSTM_LAYERS):
        d = LSTM_D if layer == 0 else LSTM_H
        key, *gks = jax.random.split(key, 9)
        w_x = [_init(gks[g], (d, LSTM_H)) for g in range(4)]
        w_h = [_init(gks[4 + g], (LSTM_H, LSTM_H)) for g in range(4)]
        params["layers"].append(
            {
                "w": split_gate_weights(w_x, w_h),
                "b": jnp.zeros((4 * LSTM_H,), jnp.float32),
            }
        )
    key, pk = jax.random.split(key)
    params["proj"] = _init(pk, (LSTM_H, LSTM_VOCAB))
    return params


def edge_lstm(xs, params):
    """Stacked LSTM over ``[T, B, D]``; returns ``[B, VOCAB]`` logits
    from the final hidden state."""
    b = xs.shape[1]
    h = xs
    for layer in params["layers"]:
        h0 = jnp.zeros((b, LSTM_H), xs.dtype)
        c0 = jnp.zeros((b, LSTM_H), xs.dtype)
        h, (h_t, _) = lstm_layer(h, h0, c0, layer["w"], layer["b"])
    return pascal_matmul(h_t, params["proj"])


JOINT_ENC = 128
JOINT_PRED = 128
JOINT_HIDDEN = 128
JOINT_VOCAB = 256


def make_joint_params(key=None):
    """Deterministic transducer-joint parameters."""
    key = key if key is not None else jax.random.PRNGKey(0x701)
    k0, k1 = jax.random.split(key)
    return {
        "fc0": _init(k0, (JOINT_ENC + JOINT_PRED, JOINT_HIDDEN)),
        "fc1": _init(k1, (JOINT_HIDDEN, JOINT_VOCAB)),
    }


def transducer_joint(enc, pred, params):
    """RNN-T joint over ``[B, He]``/``[B, Hp]``: the Family-3 MVM path.

    Batch-1 requests use the Jacquard MVM kernel (the deployment shape);
    batched requests use Pascal.
    """
    x = jnp.concatenate([enc, pred], axis=1)
    if x.shape[0] == 1:
        h = jacquard_mvm(x[0], params["fc0"])[None, :]
        h = jax.nn.relu(h)
        return jacquard_mvm(h[0], params["fc1"])[None, :]
    h = jax.nn.relu(pascal_matmul(x, params["fc0"]))
    return pascal_matmul(h, params["fc1"])


# ----------------------------------------------------------------------
# Jitted entry points with baked parameters (the AOT export surface)
# ----------------------------------------------------------------------


@functools.cache
def cnn_fn():
    """`fn(x[B,32,32,3]) -> (logits,)` with baked parameters."""
    params = make_cnn_params()
    return lambda x: (edge_cnn(x, params),)


@functools.cache
def lstm_fn():
    """`fn(xs[T,B,D]) -> (logits,)` with baked parameters."""
    params = make_lstm_params()
    return lambda xs: (edge_lstm(xs, params),)


@functools.cache
def joint_fn():
    """`fn(enc[B,He], pred[B,Hp]) -> (logits,)` with baked parameters."""
    params = make_joint_params()
    return lambda enc, pred: (transducer_joint(enc, pred, params),)
