"""Build-time Python package: JAX models (L2) + Pallas kernels (L1).

Nothing in this package runs on the request path. ``make artifacts``
invokes :mod:`compile.aot` once; the Rust coordinator then loads the
resulting HLO-text artifacts through PJRT.
"""
